"""The DataLoader: sampler + worker pool + device prefetch, parameterized by
exactly the two knobs DPT tunes (nWorker, nPrefetch) plus the device-buffer
depth.  ``measure_transfer_time`` is the paper's objective function
("Measure Dataloader Transfer Time using i, j arguments", Algorithm 1 l.12).

Hot-swap: ``DataLoader.apply_params`` reconfigures a *running* stream.
``LoaderStream`` drains the current worker pool at a batch boundary (every
batch the pool already pulled is delivered; the stateful ShardedSampler is
never rewound) and restarts with the new (nWorker, nPrefetch) — zero
batches lost or duplicated.  This is what lets the OnlineTuner
(repro.tuning.online) retune mid-training instead of only as a preamble.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.monitor import (MemoryBudget, MemoryMonitor, MemoryOverflow,
                                estimate_loader_footprint)
from repro.data.arena import SlabArena
from repro.data.cache import CachedStorage, CacheTier
from repro.data.costs import SampleCostTracker
from repro.data.dataset import Dataset
from repro.data.faults import (FaultPolicy, FaultStats, QuarantineLog,
                               RetryPolicy)
from repro.data.prefetcher import DevicePrefetcher
from repro.data.sampler import SamplerState, ShardedSampler
from repro.data.storage import storage_io_counters
from repro.data.worker_pool import (ProcessWorkerPool, ThreadWorkerPool,
                                    batch_nbytes)


@dataclasses.dataclass(frozen=True)
class LoaderParams:
    """The tunable surface.  (num_workers, prefetch_factor) are the paper's
    (nWorker, nPrefetch); device_prefetch is the TPU-side double-buffer.

    Fast-path knobs (DESIGN.md §3): ``fast_path`` enables batched storage
    reads + the vectorized transform when the dataset supports them (falls
    back silently otherwise); ``zero_copy`` additionally collates into a
    recycled slab arena — batches are then valid only until the next batch
    is requested (copy fields you keep); ``ordered`` turns on the
    order-preserving reordering buffer so delivery matches sampler order at
    any worker count; ``transfer_threads``/``donate_transfer`` configure the
    device prefetcher's HBM copy lanes.

    IO-locality knobs (DESIGN.md §5): ``locality_chunk`` (0/1 = fully
    random) switches the sampler to chunked shuffling so cold-epoch
    ``read_batch`` calls coalesce into contiguous runs — the third axis
    DPT's grid searches next to (nWorker, nPrefetch); ``staging_buffers``
    sizes the device edge's pinned staging ring (0 disables it, restoring
    the per-batch verify-and-re-put).  Both hot-swap via ``apply_params``
    (locality latches at the next epoch boundary — see
    ``ShardedSampler.set_locality``).

    Cache knob (DESIGN.md §7): ``cache_budget_bytes`` (0 = off) bounds the
    host-level cross-epoch ``CacheTier`` that retains raw items so epochs
    2+ stream at memory speed — the fourth DPT axis.  Hot-swaps via
    ``apply_params`` like locality (the cache *plan* — the sampler's
    hot/cold interleave — latches at an epoch boundary; the tier itself
    is resized in place, never dropped).

    Slow-lane knobs (DESIGN.md §9): ``slow_lane_workers`` (0 = off, the
    fifth DPT axis) adds that many dedicated workers whose sequence window
    runs ``slow_lane_lookahead`` batches ahead, taking batches the cost
    tracker predicts slow (≥ ``slow_lane_threshold`` × the median item
    cost) so a straggler is already done when ordered delivery reaches it.
    Ordered thread pools only (process pools translate the knob into
    early ``apply_async`` submission; unordered delivery has no
    head-of-line pathology to fix, so the lane is inert there).

    Fault-tolerance knobs (DESIGN.md §10): ``retry_attempts`` retries per
    item-attributed transient read fault (with ``retry_backoff_s``
    exponential jittered backoff, the whole read bounded by
    ``retry_deadline_s`` — the budget that also rides out storage-wide
    brownouts); ``on_bad_sample`` declares how a batch completes when an
    item exhausts its retries or is permanently corrupt: ``"raise"``
    (pool-fatal, the legacy default), ``"skip"`` (drop the quarantined
    ids — delivered multiset = epoch permutation minus quarantine), or
    ``"substitute"`` (deterministically resample replacements).
    ``degraded_fault_rate`` (0 = off) is the windowed fault rate at which
    the loader flips its cache tier to serve-hits-first read-only mode
    until the storage heals.
    """
    num_workers: int = 0
    prefetch_factor: int = 2
    device_prefetch: int = 2
    use_processes: bool = False
    fast_path: bool = True
    zero_copy: bool = False
    ordered: bool = True
    transfer_threads: int = 1
    donate_transfer: bool = False
    locality_chunk: int = 0
    staging_buffers: int = 2
    cache_budget_bytes: int = 0
    slow_lane_workers: int = 0
    slow_lane_threshold: float = 4.0
    slow_lane_lookahead: int = 8
    retry_attempts: int = 2
    retry_backoff_s: float = 0.01
    retry_deadline_s: float = 2.0
    on_bad_sample: str = "raise"
    degraded_fault_rate: float = 0.5

    def __post_init__(self):
        if self.use_processes and not self.ordered:
            # ProcessWorkerPool delivery is inherently ordered (imap
            # submission order): silently honouring ordered=False would
            # hand back ordered batches under an unordered contract
            raise ValueError(
                "ordered=False is unsupported with use_processes=True "
                "(process delivery is always ordered); use threads for "
                "completion-order delivery")
        if self.slow_lane_workers < 0:
            raise ValueError("slow_lane_workers must be >= 0")
        if self.slow_lane_lookahead < 0:
            raise ValueError("slow_lane_lookahead must be >= 0")
        if self.slow_lane_threshold <= 1.0:
            raise ValueError("slow_lane_threshold must be > 1.0 (it is a "
                             "multiple of the median item cost)")
        if self.on_bad_sample not in ("raise", "skip", "substitute"):
            raise ValueError(
                "on_bad_sample must be 'raise', 'skip' or 'substitute', "
                f"got {self.on_bad_sample!r}")
        if self.retry_attempts < 0:
            raise ValueError("retry_attempts must be >= 0")
        if self.retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        if self.retry_deadline_s <= 0:
            raise ValueError("retry_deadline_s must be > 0")
        if not 0.0 <= self.degraded_fault_rate <= 1.0:
            raise ValueError("degraded_fault_rate must be in [0, 1] "
                             "(0 disables degraded mode)")

    def replace(self, **kw) -> "LoaderParams":
        return dataclasses.replace(self, **kw)

    def arena_capacity(self) -> int:
        """Slab-ring size: every queueable batch + the device buffers.

        With the slow lane on, the pulled-but-undelivered span widens to
        window (queue depth + all workers) + lookahead, and every such
        batch may hold a slot (acquire-before-pull liveness: see
        ``ThreadWorkerPool._acquire_slot``) — size for it, or early-started
        slow batches could exhaust the slots the head sequence needs.
        """
        base = max(2, self.num_workers * self.prefetch_factor
                   + self.device_prefetch)
        if self.slow_lane_workers > 0 and self.ordered \
                and not self.use_processes:
            base += (self.num_workers + self.slow_lane_workers
                     + self.slow_lane_lookahead + 1)
        return base


@dataclasses.dataclass
class TransferStats:
    seconds: float
    batches: int
    bytes: int
    overflowed: bool = False
    peak_loader_bytes: int = 0
    # per-batch arrival deltas (wall-clock evaluators fill this in); the
    # variance-aware win test in repro.tuning needs samples, not just a mean
    batch_seconds: Optional[List[float]] = None
    # IO-efficiency counters (DESIGN.md §5): storage requests issued during
    # the window, mean cache-miss items served per request (the measured
    # coalesced run length), and the device edge's staging-pool hit rate —
    # so retune decisions and benches see *locality*, not just bytes/s.
    # Zero/None when the storage backend keeps no counters / no staging ran.
    coalesced_requests: int = 0
    coalesced_run_len: float = 0.0
    staging_hit_rate: Optional[float] = None
    # cache effectiveness over the window (DESIGN.md §7): items served
    # from a cache (the cross-epoch tier and/or the storage's own page
    # cache) vs items that paid real IO.  Zero when nothing caches.
    cache_hits: int = 0
    cache_misses: int = 0
    # tail-cost signals (DESIGN.md §9): the cost tracker's estimated
    # per-item mean and p99 decode+IO seconds, and how many batches the
    # window routed to the slow lane.  Zero when no tracker ran.
    sample_cost_mean_s: float = 0.0
    sample_cost_p99_s: float = 0.0
    slow_batches: int = 0
    # fault-plane health over the window (DESIGN.md §10): retried reads,
    # raised faults, newly-quarantined items, process-worker resubmits,
    # and whether the loader ended the window in degraded (cache
    # read-only) mode.  Zero/False on a healthy storage.
    read_retries: int = 0
    read_faults: int = 0
    quarantined: int = 0
    resubmits: int = 0
    degraded: bool = False

    @property
    def bytes_per_second(self) -> float:
        return self.bytes / self.seconds if self.seconds > 0 else 0.0


class LoaderStream:
    """A live, hot-swappable batch stream over the loader's stateful sampler.

    ``apply_params`` retunes the stream in place: the current worker pool
    stops pulling new index-batches (``request_drain``), everything it
    already pulled is delivered in turn, then a fresh pool starts with the
    new (num_workers, prefetch_factor) from exactly the sampler position
    where the old pool stopped.  The swap is requested from any thread and
    performed by whoever consumes the stream; ``swaps`` counts completed
    swaps.  ``device_prefetch`` depth is hot-swapped too: the live
    prefetcher's depth gate is retargeted at the same boundary.

    ``apply_reshard`` is the elastic fleet transition (a host died or
    joined).  Unlike a params swap, a reshard must NOT deliver what the
    pool pre-pulled under the old shard map — those index-batches belong
    to the old topology.  The stream stops yielding at the agreed global
    batch barrier (``at_batch``), discards the pool (every in-flight arena
    slot still returns), rewinds the sampler to exactly the delivered
    position, remaps (shard, num_shards), and restarts — so the batches a
    consumer sees are precisely: old-shard slices of global batches before
    the barrier, new-shard slices after it.  Optional ``makeup`` index
    chunks (a dead host's undelivered slices, redistributed by the
    coordinator) are delivered first after the barrier.  ``position`` is
    the stream's absolute global-batch cursor; exact accounting relies on
    ordered delivery (``LoaderParams.ordered``, the default).
    """

    def __init__(self, loader: "DataLoader", *, to_device: bool = True):
        self.loader = loader
        self.to_device = to_device
        self.swaps = 0
        self.reshards = 0
        # schedule-aware: epochs can have different lengths once the
        # geometry schedule has more than one step
        self.position = loader.sampler.absolute()
        # per-yield position log: makeup yields do not advance ``position``,
        # so a consumer's absolute regular-batch position after its k-th
        # consumed yield is position_after(k), NOT initial + k.  The fleet
        # coordinator's makeup accounting for a dead host relies on this
        # (counting observes as regular batches loses samples as soon as a
        # host that consumed makeup dies).
        self.yields = 0
        self._initial_position = self.position
        self._pos_log: deque = deque()
        self._pos_log_base = 0           # yield index of _pos_log[0]
        self._pending: Optional[LoaderParams] = None
        self._pending_locality_epoch: Optional[int] = None
        self._pending_reshard: Optional[
            Tuple[int, int, int, Optional[Tuple[int, ...]]]] = None
        self._pending_makeup: List[np.ndarray] = []  # held until the barrier
        self._makeup: deque = deque()        # index chunks awaiting delivery
        # one flag per index-batch the pool pulled, in pull order (ordered
        # delivery preserves it): True = makeup chunk, whose yield must NOT
        # advance the regular-batch position
        self._pull_kinds: deque = deque()
        # makeup chunks the current pool pulled but has not delivered yet:
        # a reshard's discard boundary regenerates regular batches by
        # rewinding the sampler, but pulled makeup exists nowhere else —
        # it must be pushed back onto the queue or the samples are lost
        self._inflight_makeup: deque = deque()
        # makeup chunks tagged with the yield index that delivered them:
        # yielded-into-a-prefetcher is not consumed, so a dead host's
        # coordinator asks for makeup past its CONSUMED yield count
        # (undelivered_makeup(consumed_yields=...)) — popping at yield
        # time alone would lose prefetcher-buffered makeup with the host
        self._yielded_makeup: deque = deque()   # (yield index, chunk)
        self._lock = threading.Lock()
        self._prefetcher: Optional[DevicePrefetcher] = None
        self._host_gen = self._host_stream()
        if to_device:
            self._prefetcher = DevicePrefetcher(
                self._host_gen, depth=loader.params.device_prefetch,
                sharding=loader.sharding,
                transfer_threads=loader.params.transfer_threads,
                donate=loader.params.donate_transfer,
                staging_buffers=loader.params.staging_buffers)
            self._iter = iter(self._prefetcher)
        else:
            self._iter = self._host_gen

    def close(self) -> None:
        """Tear the stream down deterministically: stop the prefetcher,
        close the host generator (its finally shuts the pool down), and
        return every in-flight arena slot to the loader's arena — so an
        abandoned stream can never strand slots a future stream needs."""
        if self._prefetcher is not None:
            self._prefetcher.close()
        self._host_gen.close()

    def apply_params(self, params: LoaderParams, *,
                     locality_epoch: Optional[int] = None) -> None:
        """Request a hot swap; takes effect at the next batch boundary.

        ``locality_epoch`` pins the epoch the new ``locality_chunk``
        latches at (fleet-uniform pushes; see ``ShardedSampler
        .set_locality``); None keeps the per-host natural latch.
        """
        with self._lock:
            self._pending = params
            self._pending_locality_epoch = locality_epoch

    def apply_reshard(self, num_shards: int, shard: int, *,
                      at_batch: Optional[int] = None,
                      makeup: Optional[Sequence[np.ndarray]] = None,
                      sizes: Optional[Sequence[int]] = None) -> int:
        """Request an elastic reshard at global batch ``at_batch``.

        ``at_batch`` is an absolute global-batch position; None means the
        next batch boundary.  If the stream has already yielded past it,
        the boundary is clamped up to ``position`` and the EFFECTIVE
        boundary is returned — the coordinator re-issues the request to
        the whole fleet at the max effective boundary until it is common
        (once a request is pending the stream cannot yield past its
        boundary, so the negotiation converges).  ``makeup`` index chunks
        are delivered right after the barrier, before regular new-shard
        batches; post-settlement chunks arrive via :meth:`add_makeup`.
        ``sizes`` is an explicit per-shard split of the global batch
        (ragged survivor counts, per-host consensus weights); see
        ``ShardedSampler.reshard``.
        """
        with self._lock:
            boundary = self.position if at_batch is None \
                else max(at_batch, self.position)
            self._pending_reshard = (
                num_shards, shard, boundary,
                tuple(int(s) for s in sizes) if sizes is not None else None)
            if makeup:
                # held back until the barrier commits: the pool running
                # NOW must not interleave makeup with old-shard batches
                self._pending_makeup.extend(
                    np.asarray(m) for m in makeup if len(m))
            return boundary

    def undelivered_makeup(self, consumed_yields: Optional[int] = None
                           ) -> List[np.ndarray]:
        """Makeup chunks accepted but not yet delivered (queued, pulled
        in-flight, or parked behind a pending reshard).  A fleet
        coordinator re-redistributes these when THIS host leaves — makeup
        parked on a corpse is otherwise lost.

        ``consumed_yields`` additionally recovers makeup the stream
        *yielded* past that count — batches sitting in a device
        prefetcher the dead host never consumed (None assumes every
        yield was consumed, exact for undecorated host streams)."""
        with self._lock:
            out = (list(self._inflight_makeup) + list(self._makeup)
                   + list(self._pending_makeup))
            if consumed_yields is not None:
                out = [c for y, c in self._yielded_makeup
                       if y > consumed_yields] + out
            return out

    def position_after(self, consumed_yields: int) -> int:
        """Absolute regular-batch position after this stream's first
        ``consumed_yields`` yields (makeup yields do not advance it).

        The log is pruned up to the queried point, so callers must query
        with nondecreasing counts — a consumer tracking its own progress
        does.  Queries past the log's tail return the current position.
        """
        if consumed_yields <= 0:
            return self._initial_position
        with self._lock:
            while len(self._pos_log) > 1 \
                    and self._pos_log_base < consumed_yields - 1:
                self._pos_log.popleft()
                self._pos_log_base += 1
            if not self._pos_log:
                return self._initial_position if self.yields == 0 \
                    else self.position
            idx = consumed_yields - 1 - self._pos_log_base
            if idx < 0:                  # pruned past (capped log)
                return self._pos_log[0]
            if idx >= len(self._pos_log):  # consumer claims > yielded
                return self._pos_log[-1]
            return self._pos_log[idx]

    def add_makeup(self, makeup: Sequence[np.ndarray]) -> None:
        """Queue makeup index chunks for delivery.

        Before the reshard commits they are parked with the pending
        request; afterwards they go straight into the live feed (the
        pull-kind FIFO keeps position accounting exact wherever they
        interleave).
        """
        with self._lock:
            arrays = [np.asarray(m) for m in makeup if len(m)]
            if self._pending_reshard is not None:
                self._pending_makeup.extend(arrays)
            else:
                self._makeup.extend(arrays)

    # ---- internals ---------------------------------------------------------
    def _reshard_due_locked(self) -> bool:
        return (self._pending_reshard is not None
                and self.position >= self._pending_reshard[2])

    def _commit_reshard(self) -> None:
        """At the barrier, with no pool running: rewind the sampler to the
        delivered position, remap the shard, and re-spec the slab arena
        (the local batch shape changed)."""
        with self._lock:
            num_shards, shard, _, sizes = self._pending_reshard
            self._pending_reshard = None
            # makeup the discarded pool pulled but never delivered goes
            # back to the FRONT of the queue (it was next in line); the
            # chunks are absolute sample indices, so they remain valid
            # under the new shard map
            self._makeup.extendleft(reversed(self._inflight_makeup))
            self._inflight_makeup.clear()
            self._makeup.extend(self._pending_makeup)
            self._pending_makeup = []
            # pulled-but-undelivered flags belong to the discarded pool
            self._pull_kinds.clear()
        sampler = self.loader.sampler
        sampler.state = sampler.state_at(self.position)
        sampler.reshard(num_shards, shard, sizes=sizes)
        if self.loader._stream_arena is not None:
            # only batches of the NEW local size may establish the fresh
            # spec — a ragged makeup chunk must not pin the arena shape
            self.loader._stream_arena.respec(
                expected_leading=sampler.local_batch)
        # the cache tier keys on ABSOLUTE sample indices, so a shard remap
        # leaves every resident item valid: re-spec, never drop
        self.loader._sync_cache_plan()
        self.reshards += 1

    def _indices(self):
        """The pool's index feed: queued makeup chunks first (pulled from
        the shared deque, so chunks an outgoing pool never pulled remain
        for the next pool), then the stateful sampler.  Each pull logs its
        kind so the consumer can tell a yielded makeup batch (no position
        advance) from a regular one at any interleaving."""
        sampler_it = iter(self.loader.sampler)
        last_lb = self.loader.sampler.local_batch
        while True:
            with self._lock:             # pool pump thread vs. consumer /
                idx = None               # coordinator readers
                if self._makeup:
                    idx = self._makeup.popleft()
                    self._pull_kinds.append(True)
                    self._inflight_makeup.append(idx)
            if idx is not None:
                yield idx
            else:
                idx = next(sampler_it)
                if len(idx) != last_lb:
                    # a geometry latch crossed an epoch boundary (or the
                    # split went ragged): the local batch changed shape,
                    # so the slab arena must re-spec — in-flight slots of
                    # the old spec drain out via their generation stamp
                    last_lb = len(idx)
                    arena = self.loader._stream_arena
                    if arena is not None:
                        arena.respec(expected_leading=last_lb)
                self._pull_kinds.append(False)
                yield idx

    def _note_skip(self) -> None:
        """A pool-level skip (fault policy dropped an all-quarantined
        batch) consumed one pulled index-batch without a yield: pop its
        pull-kind so the FIFO stays aligned, and advance the regular-batch
        cursor — the sampler moved past it.  A skipped makeup chunk is
        consumed, not re-queued: its samples are quarantined.  Runs on the
        consumer thread, in delivery order, like the accounting below."""
        with self._lock:
            if self._pull_kinds and self._pull_kinds.popleft():
                if self._inflight_makeup:
                    self._inflight_makeup.popleft()
            else:
                self.position += 1

    def _host_stream(self):
        while True:
            with self._lock:
                due = self._reshard_due_locked()
            if due:
                self._commit_reshard()
            pool, _monitor = self.loader._pool(self._indices(),
                                               for_stream=True,
                                               on_skip=self._note_skip)
            draining = False
            resharding = False
            it = iter(pool)
            try:
                while True:
                    with self._lock:
                        if self._reshard_due_locked():
                            resharding = True
                    if resharding:
                        # discard boundary: pre-pulled batches belong to
                        # the old shard map and must not be delivered
                        break
                    if not draining and self._pending is not None:
                        pool.request_drain()
                        draining = True
                    try:
                        batch = next(it)
                    except StopIteration:
                        break
                    # account BEFORE the yield: the generator parks there,
                    # and the consumer holding the batch means the position
                    # has advanced past it.  The pull-kind FIFO (ordered
                    # delivery preserves pull order) tells makeup batches —
                    # which never advance the position — from regular ones.
                    # under the lock: with to_device=True this loop runs
                    # on the prefetcher thread while consumed_position /
                    # undelivered_makeup read the same structures from
                    # the trainer or coordinator thread
                    with self._lock:
                        if self._pull_kinds and self._pull_kinds.popleft():
                            chunk = self._inflight_makeup.popleft()
                            self._yielded_makeup.append((self.yields + 1,
                                                         chunk))
                            if len(self._yielded_makeup) > 1024:
                                self._yielded_makeup.popleft()
                        else:
                            self.position += 1
                        self.yields += 1
                        self._pos_log.append(self.position)
                        if len(self._pos_log) > 65536:   # unconsulted cap
                            self._pos_log.popleft()
                            self._pos_log_base += 1
                    yield batch
            finally:
                # normal end (drain swap / reshard discard) or the stream
                # being closed/abandoned: either way every in-flight slot
                # must return to the arena
                it.close()
                pool.shutdown()
            with self._lock:
                params, self._pending = self._pending, None
                latch, self._pending_locality_epoch = \
                    self._pending_locality_epoch, None
            if params is not None:
                # re-assert the pending params at the boundary: trial
                # measurements may have mutated loader.params via
                # with_params between the request and this drain
                self.loader.params = params
                # locality latches at the next epoch boundary — an
                # in-progress epoch keeps its permutation (coverage);
                # a fleet push pins one common latch epoch instead
                self.loader.sampler.set_locality(params.locality_chunk,
                                                 epoch=latch)
                # the cache tier survives the swap (resized in place); the
                # sampler's hot/cold interleave latches at the same epoch
                self.loader._sync_cache_plan(epoch=latch)
                self.swaps += 1
                if self._prefetcher is not None:
                    self._prefetcher.set_depth(params.device_prefetch)
                    self._prefetcher.set_staging(params.staging_buffers)

    def __iter__(self):
        return self

    def __next__(self):
        return next(self._iter)


class DataLoader:
    def __init__(self, dataset: Dataset, global_batch: int, *,
                 params: LoaderParams = LoaderParams(),
                 shuffle: bool = True, seed: int = 0,
                 host_index: int = 0, host_count: int = 1,
                 memory_budget: Optional[MemoryBudget] = None,
                 sharding=None,
                 sampler_state: Optional[SamplerState] = None):
        self.dataset = dataset
        self.params = params
        self.memory_budget = memory_budget
        self.sharding = sharding
        self._live_stream: Optional[LoaderStream] = None
        self._stream_arena: Optional[SlabArena] = None
        self._cache_tier: Optional[CacheTier] = None
        self._mean_item_nbytes: Optional[float] = None
        # per-item cost EWMAs persist across pools/streams/hot swaps: the
        # slow-lane predictor must survive the very retune that enables it
        self.cost_tracker = SampleCostTracker(
            len(dataset), threshold=params.slow_lane_threshold)
        # fault-plane state (DESIGN.md §10), shared by every pool this
        # loader creates: the quarantine rides state_dict like costs, and
        # the stats' degraded flip drives the cache tier's read-only mode
        self.quarantine = QuarantineLog()
        self.fault_stats = FaultStats(
            degraded_enter=params.degraded_fault_rate,
            on_degraded=self._on_degraded)
        self.sampler = ShardedSampler(
            len(dataset), global_batch, shuffle=shuffle, seed=seed,
            host_index=host_index, host_count=host_count,
            state=sampler_state, locality_chunk=params.locality_chunk)
        if params.cache_budget_bytes > 0:
            self._sync_cache_plan()

    @property
    def global_batch(self) -> int:
        """The current epoch's global batch (elastic — follows the
        sampler's geometry schedule)."""
        return self.sampler.global_batch

    def set_geometry(self, global_batch: int, *,
                     epoch: Optional[int] = None) -> int:
        """Change the global batch, epoch-latched (see ``ShardedSampler
        .set_geometry``).  A live stream needs no restart: batch
        boundaries only move from the latch epoch on, the stateful
        sampler crosses into the new geometry naturally, and the stream's
        index feed re-specs the slab arena when the local batch shape
        changes.  Returns the effective first epoch."""
        return self.sampler.set_geometry(global_batch, epoch=epoch)

    # ---- fault plane (DESIGN.md §10) ---------------------------------------
    def _on_degraded(self, degraded: bool) -> None:
        """Degraded-mode flip: the cache tier serves hits but admits
        nothing while the storage is browning out (read-only survives a
        flush-refill cycle the failing reads could never win), and goes
        back to normal admission once successes dilute the fault rate."""
        tier = self._cache_tier
        if tier is not None:
            tier.read_only = degraded

    def _on_quarantine(self, ids: List[int]) -> None:
        """Quarantined items exit cost tracking: a permanently-failing id
        must stop dragging the tail stats and slow-lane routing."""
        self.cost_tracker.forget(ids)

    def _fault_policy(self) -> FaultPolicy:
        """The policy pools run reads through, rebuilt per pool from the
        (hot-swappable) params; the quarantine/stats live on the loader."""
        p = self.params
        self.fault_stats.degraded_enter = max(0.0, p.degraded_fault_rate)
        return FaultPolicy(
            retry=RetryPolicy(attempts=p.retry_attempts,
                              backoff_s=p.retry_backoff_s,
                              deadline_s=p.retry_deadline_s),
            quarantine=self.quarantine, stats=self.fault_stats,
            on_bad_sample=p.on_bad_sample, num_items=len(self.dataset),
            seed=getattr(self.sampler, "seed", 0),
            on_quarantine=self._on_quarantine)

    # ---- cache tier (DESIGN.md §7) -----------------------------------------
    @property
    def cache_tier(self) -> Optional[CacheTier]:
        return self._cache_tier

    def _item_nbytes_mean(self) -> float:
        if self._mean_item_nbytes is None:
            st = self.dataset.storage
            n = min(len(st), 16)
            sizes = [st.item_nbytes(i) for i in range(n)] or [0]
            self._mean_item_nbytes = float(np.mean(sizes))
        return self._mean_item_nbytes

    def _ensure_tier(self) -> int:
        """Create or re-spec the cross-epoch cache tier from the current
        params; returns the planned hot-chunk count.  The tier is owned by
        the loader and persists across hot swaps and reshards — a budget
        change is a resize (trim/grow), never a flush."""
        p = self.params
        budget = max(0, p.cache_budget_bytes)
        chunk = max(1, p.locality_chunk)
        if budget <= 0:
            if self._cache_tier is not None:
                self._cache_tier.reconfigure(budget_bytes=0, chunk=chunk)
            return 0
        if self._cache_tier is None:
            # the live stream's slab arena shares the budget: its in-use
            # bytes are deducted from the tier's effective budget (late
            # bound — the arena is created lazily by the first stream)
            def arena_bytes() -> int:
                arena = self._stream_arena
                return arena.nbytes_in_use() if arena is not None else 0

            self._cache_tier = CacheTier(
                budget, chunk=chunk, num_items=len(self.dataset),
                item_nbytes=self._item_nbytes_mean(),
                arena_bytes=arena_bytes)
        else:
            self._cache_tier.reconfigure(
                budget_bytes=budget, chunk=chunk,
                num_items=len(self.dataset),
                item_nbytes=self._item_nbytes_mean())
        return self._cache_tier.hot_chunks

    def _sync_cache_plan(self, *, epoch: Optional[int] = None) -> None:
        """Re-derive the tier spec AND the sampler's hot/cold interleave
        from the current params.  Called wherever ``set_locality`` is —
        the plan changes the epoch permutation, so it rides the exact same
        epoch latch (a fleet pins one common epoch for both)."""
        self.sampler.set_cache_plan(self._ensure_tier(), epoch=epoch)

    def _cached_dataset(self, *, admit: bool) -> Dataset:
        """The dataset as read through the cache tier (identity when the
        tier is off or a process pool would fork it away)."""
        if (self._cache_tier is None or self._cache_tier.budget_bytes <= 0
                or self._uses_processes()):
            return self.dataset
        return self.dataset.with_storage(
            CachedStorage(self.dataset.storage, self._cache_tier,
                          admit=admit))

    # ---- checkpointable state ---------------------------------------------
    def state_dict(self):
        return {"sampler": self.sampler.state.to_dict(),
                "params": dataclasses.asdict(self.params),
                "locality": self.sampler.locality_state(),
                "cache_plan": self.sampler.cache_state(),
                "geometry": self.sampler.geometry_state(),
                "shard_sizes": list(self.sampler.shard_sizes)
                if self.sampler.shard_sizes is not None else None,
                "costs": self.cost_tracker.state_dict(),
                "quarantine": self.quarantine.state_dict()}

    def load_state_dict(self, d):
        self.sampler.state = SamplerState.from_dict(d["sampler"])
        self.params = LoaderParams(**d["params"])
        if "locality" in d:
            # the full schedule restores a mid-epoch deferred change exactly
            self.sampler.load_locality(d["locality"])
        else:                          # pre-locality checkpoint
            self.sampler.force_locality(self.params.locality_chunk)
        if "geometry" in d:            # pre-elastic checkpoints keep the
            self.sampler.load_geometry(d["geometry"])   # constructed batch
        if d.get("shard_sizes") is not None:
            self.sampler._shard_sizes = tuple(
                int(s) for s in d["shard_sizes"])
        hot_k = self._ensure_tier()    # re-spec (never flush) the tier
        if "cache_plan" in d:
            self.sampler.load_cache_plan(d["cache_plan"])
        else:                          # pre-cache checkpoint
            self.sampler.force_cache_plan(hot_k)
        if "costs" in d:               # pre-costs checkpoints start cold
            self.cost_tracker.load_state_dict(d["costs"])
        if "quarantine" in d:          # pre-fault checkpoints start clean
            self.quarantine.load_state_dict(d["quarantine"])

    def with_params(self, params: LoaderParams) -> "DataLoader":
        """Set params for *future* pools (trial measurements, restarts).
        Does not swap a live stream's pool — use ``apply_params`` for
        that.  ``locality_chunk`` does latch into the (shared) sampler
        schedule, effective from the next epoch that hasn't started — so
        a restart honours it; a live stream keeps its current epoch's
        order either way.  (DPT trials never hit this: they preserve the
        loader's locality via ``replace`` and measure candidate chunks
        through the ``measure_transfer_time(locality_chunk=...)``
        override.)"""
        self.params = params
        self.sampler.set_locality(params.locality_chunk)
        self._sync_cache_plan()
        return self

    def apply_params(self, params: LoaderParams, *,
                     locality_epoch: Optional[int] = None) -> LoaderParams:
        """Hot-swap tuned parameters in.

        ``self.params`` is set immediately (any future pool — a new
        stream, a trial measurement default — uses the new values even if
        the current stream was abandoned mid-iteration), and the latest
        live ``stream()`` is asked to swap at its next batch boundary
        (pool drained, sampler position preserved, no batch lost or
        duplicated).  ``locality_epoch`` pins the epoch a changed
        ``locality_chunk`` latches at (fleet-uniform pushes must land on
        one common epoch across hosts; see ``locality_latch_epoch``).
        """
        self.params = params
        if self._live_stream is not None:
            # sampler locality syncs when the stream commits the swap
            self._live_stream.apply_params(params,
                                           locality_epoch=locality_epoch)
        else:
            self.sampler.set_locality(params.locality_chunk,
                                      epoch=locality_epoch)
            self._sync_cache_plan(epoch=locality_epoch)
        return params

    def locality_latch_epoch(self) -> int:
        """The earliest epoch a locality change pushed NOW is guaranteed
        to be latchable at, accounting for producer run-ahead.

        The sampler's producer cursor advances ahead of delivery by at
        most the pipeline's in-flight capacity (worker queues + device
        prefetch) before a pending swap pins it, so a chunk pinned to
        this epoch can always be honoured exactly — the per-host clamp
        in ``set_locality`` never has to move it.  A fleet coordinator
        takes the max over hosts and pushes that one epoch everywhere.
        """
        p = self.params
        inflight = p.num_workers * p.prefetch_factor + p.device_prefetch + 1
        if p.slow_lane_workers > 0 and p.ordered:
            # the slow lane's wider sequence window lets the producer pull
            # that much further ahead of delivery
            inflight += p.slow_lane_workers + p.slow_lane_lookahead
        return self.sampler.latch_epoch_for(
            self.sampler.absolute() + inflight)

    def reshard(self, num_shards: int, shard: int, *,
                at_batch: Optional[int] = None,
                makeup: Optional[Sequence[np.ndarray]] = None,
                sizes: Optional[Sequence[int]] = None) -> int:
        """Elastic reshard: remap this host's shard of the global stream.

        With a live stream the remap happens at the ``at_batch`` barrier
        via :meth:`LoaderStream.apply_reshard` (in-flight old-shard batches
        discarded, sampler rewound to the delivered position, optional
        ``makeup`` chunks delivered first).  Without one the sampler is
        remapped in place — its position IS the consumed position; makeup
        would have no delivery channel, so it is rejected.  Returns the
        effective barrier (see ``apply_reshard``).
        """
        if self._live_stream is not None:
            return self._live_stream.apply_reshard(
                num_shards, shard, at_batch=at_batch, makeup=makeup,
                sizes=sizes)
        if makeup:
            raise ValueError("makeup delivery needs a live stream; "
                             "start one with stream() first")
        self.sampler.reshard(num_shards, shard, sizes=sizes)
        return self.sampler.absolute()

    def add_makeup(self, makeup: Sequence[np.ndarray]) -> None:
        """Queue makeup chunks on the live stream (see
        ``LoaderStream.add_makeup``)."""
        if self._live_stream is None:
            raise ValueError("makeup delivery needs a live stream; "
                             "start one with stream() first")
        self._live_stream.add_makeup(makeup)

    def undelivered_makeup(self, consumed_yields: Optional[int] = None
                           ) -> List[np.ndarray]:
        """Makeup chunks the live stream has accepted but not delivered
        (empty without a stream; see ``LoaderStream.undelivered_makeup``
        for ``consumed_yields``)."""
        if self._live_stream is None:
            return []
        return self._live_stream.undelivered_makeup(consumed_yields)

    # ---- iteration ----------------------------------------------------------
    def _arena(self, *, for_stream: bool) -> Optional[SlabArena]:
        """The slab arena for a new pool, when zero-copy engages.

        The live stream's arena is owned by the loader and persists across
        hot swaps (a drain delivers every in-flight slot and the consumer's
        releases return them here, so the new pool starts with warm slabs);
        side-channel pools (trial measurements racing the live stream,
        one-epoch ``host_batches``) get their own throwaway arena so they
        never contend with the stream for slots.
        """
        p = self.params
        use_processes = p.use_processes and p.num_workers > 0
        if not (p.fast_path and p.zero_copy and not use_processes
                and self.dataset.supports_fast_path):
            return None
        if not for_stream:
            return SlabArena(p.arena_capacity())
        if self._stream_arena is None:
            self._stream_arena = SlabArena(p.arena_capacity())
        else:
            self._stream_arena.resize(p.arena_capacity())
        return self._stream_arena

    def _pool(self, index_iter, *, for_stream: bool = False,
              dataset: Optional[Dataset] = None, on_skip=None):
        monitor = MemoryMonitor(self.memory_budget)
        cls = ProcessWorkerPool if (self.params.use_processes
                                    and self.params.num_workers > 0) \
            else ThreadWorkerPool
        if dataset is None:
            # the live stream reads (and admits) through the cache tier;
            # side-channel pools default to the plain dataset unless the
            # caller hands in its own view (trial isolation)
            dataset = self._cached_dataset(admit=True) if for_stream \
                else self.dataset
        # the (hot-swappable) threshold lives in params; the EWMA table in
        # the long-lived tracker — sync at pool birth so a retuned
        # threshold reclassifies without losing learned costs
        self.cost_tracker.threshold = self.params.slow_lane_threshold
        pool = cls(dataset, index_iter,
                   num_workers=self.params.num_workers,
                   prefetch_factor=self.params.prefetch_factor,
                   monitor=monitor,
                   ordered=self.params.ordered,
                   fast=self.params.fast_path,
                   arena=self._arena(for_stream=for_stream),
                   cost_tracker=self.cost_tracker,
                   slow_lane_workers=self.params.slow_lane_workers,
                   slow_lane_lookahead=self.params.slow_lane_lookahead,
                   fault_policy=self._fault_policy(),
                   on_skip=on_skip)
        return pool, monitor

    def host_batches(self, *, epoch: Optional[int] = None,
                     num_batches: Optional[int] = None) -> Iterator:
        """Host-side numpy batches (one epoch, or the stateful stream)."""
        idx_iter = self.sampler.epoch_iter(epoch) if epoch is not None \
            else iter(self.sampler)
        if num_batches is not None:
            idx_iter = _take(idx_iter, num_batches)
        pool, _monitor = self._pool(idx_iter)
        return iter(pool)

    def stream(self, *, to_device: bool = True) -> LoaderStream:
        """The live, hot-swappable stream (see LoaderStream).  A previous
        live stream is closed first: its worker pool would otherwise keep
        holding slots of the shared stream arena forever."""
        if self._live_stream is not None:
            self._live_stream.close()
        self._live_stream = LoaderStream(self, to_device=to_device)
        return self._live_stream

    def __iter__(self):
        """Device-side batches (stateful stream, prefetched, swappable)."""
        return iter(self.stream())

    # ---- the DPT objective ---------------------------------------------------
    def _uses_processes(self) -> bool:
        return self.params.use_processes and self.params.num_workers > 0

    def io_counters(self) -> dict:
        """Live IO-efficiency snapshot for the monitor report: storage
        request counters (+ achieved coalesced run length), the live
        stream's staging-pool hit rate, and the arena hit rate.  Empty
        when nothing in the pipeline keeps counters — including process
        pools, whose reads increment counters in the forked children, not
        here (zeros would read as "no locality", which is a lie)."""
        out: dict = {}
        c = None if self._uses_processes() \
            else storage_io_counters(self.dataset.storage)
        if c is not None:
            out.update(c)
            misses = c["reads"] - c["cache_hits"]
            out["coalesced_run_len"] = (
                misses / c["coalesced_requests"]
                if c["coalesced_requests"] else 0.0)
        tier = self._cache_tier
        if tier is not None and not self._uses_processes():
            out.update(tier.counters())
            if c is not None:
                # tier hits never reach the storage at all; fold them into
                # the request totals so cache effectiveness reads out of
                # the same reads/cache_hits split controllers already use
                # (reads - cache_hits, the true-IO miss count, is
                # unchanged: tier hits add to both sides)
                out["reads"] = c["reads"] + tier.hits
                out["cache_hits"] = c["cache_hits"] + tier.hits
        stream = self._live_stream
        if stream is not None and stream._prefetcher is not None:
            hr = stream._prefetcher.staging_hit_rate
            if hr is not None:
                out["staging_hit_rate"] = hr
        if self._stream_arena is not None:
            out["arena_hit_rate"] = self._stream_arena.hit_rate
        tracker = self.cost_tracker
        if tracker.records:
            # tail-cost signals (DESIGN.md §9): these ride HostReport.io to
            # the fleet coordinator and feed the online retune trigger
            out["sample_cost_mean_s"] = tracker.mean()
            out["sample_cost_p99_s"] = tracker.p99()
            out["sample_cost_tail_ratio"] = tracker.tail_ratio()
            out["slow_batches"] = float(tracker.slow_batches)
        fs = self.fault_stats
        if fs.read_faults or fs.read_retries or fs.resubmits \
                or len(self.quarantine) or fs.degraded:
            # fault-plane health (DESIGN.md §10): valid in process mode too
            # — children ship their tallies back and the parent merges them
            out["read_retries"] = float(fs.read_retries)
            out["read_faults"] = float(fs.read_faults)
            out["quarantined"] = float(len(self.quarantine))
            out["resubmits"] = float(fs.resubmits)
            out["degraded"] = 1.0 if fs.degraded else 0.0
            out["fault_rate"] = fs.fault_rate()
        return out

    def _prewarm_tier(self, tier: CacheTier) -> None:
        """Fill ``tier``'s hot set as a warm epoch would find it.

        Reads bypass a latency-injecting wrapper's delay (via its
        ``inner``) — the pre-warm models "these items were admitted in a
        PREVIOUS epoch", whose IO cost was already paid there, so it must
        not charge this trial's measurement window either."""
        src = getattr(self.dataset.storage, "inner", self.dataset.storage)
        n = min(tier.hot_chunks * tier.chunk, len(self.dataset.storage))
        for start in range(0, n, 256):
            idx = list(range(start, min(start + 256, n)))
            for i, item in zip(idx, src.read_batch(idx)):
                tier.admit(i, np.asarray(item))

    def measure_transfer_time(self, num_batches: int, *,
                              epoch: int = 0,
                              to_device: bool = True,
                              locality_chunk: Optional[int] = None,
                              cache_budget_bytes: Optional[int] = None,
                              slow_lane_workers: Optional[int] = None,
                              global_batch: Optional[int] = None
                              ) -> TransferStats:
        """Wall-clock time to deliver ``num_batches`` (storage->host[->HBM]).

        Raises MemoryOverflow through TransferStats.overflowed=True so
        Algorithm 1's inner-loop break can act on it.  ``locality_chunk``
        overrides the sampler's scheduled chunking for this measurement
        only (how DPT trials price the locality axis without perturbing a
        live stream's epoch order).

        ``cache_budget_bytes`` is the cache axis's measurement-only
        override: ``None`` (default) reads through the LIVE tier without
        admitting (hits are real, the trial never pollutes the cache);
        ``0`` bypasses the tier entirely; ``B > 0`` measures a throwaway
        tier of budget B — pre-warmed when ``epoch >= 1``, since a warm
        epoch finds the hot set already resident.

        ``slow_lane_workers`` is the slow-lane axis's measurement-only
        override: the trial pool runs with that lane width (sharing the
        loader's learned cost tracker — the lane is only as good as its
        predictor), ``self.params`` restored afterwards.

        ``global_batch`` is the geometry axis's measurement-only
        override: the trial iterates a THROWAWAY sampler with the
        candidate global batch (even per-host split), so DPT can price
        batch geometries without touching the live sampler's schedule or
        position.
        """
        if slow_lane_workers is not None \
                and slow_lane_workers != self.params.slow_lane_workers:
            saved = self.params
            self.params = self.params.replace(
                slow_lane_workers=slow_lane_workers)
            try:
                return self.measure_transfer_time(
                    num_batches, epoch=epoch, to_device=to_device,
                    locality_chunk=locality_chunk,
                    cache_budget_bytes=cache_budget_bytes,
                    global_batch=global_batch)
            finally:
                self.params = saved
        trial_sampler = self.sampler
        if global_batch is not None \
                and int(global_batch) != self.sampler.gb_for_epoch(epoch):
            s = self.sampler
            trial_sampler = ShardedSampler(
                s.num_items, int(global_batch), shuffle=s.shuffle,
                seed=s.seed, drop_last=s.drop_last,
                host_index=s.host_index, host_count=s.host_count,
                layout=s.layout,
                shard_sizes=ShardedSampler.even_split(int(global_batch),
                                                      s.host_count))
            trial_sampler.load_locality(s.locality_state())
            trial_sampler.load_cache_plan(s.cache_state())
        # static pre-check (the paper's N/A cells fail before running)
        if self.memory_budget is not None:
            probe = self.dataset.get_batch(
                trial_sampler.local_indices(epoch, 0, locality_chunk)[:1])
            est_batch = batch_nbytes(probe) * trial_sampler.local_batch
            est = estimate_loader_footprint(
                est_batch, self.params.num_workers,
                self.params.prefetch_factor, self.params.device_prefetch)
            if est > self.memory_budget.loader_bytes * 4:
                return TransferStats(float("inf"), 0, 0, overflowed=True)

        # the trial's read view (cache axis): live tier read-only, plain
        # dataset, or a throwaway tier — never admit into the live tier
        trial_tier: Optional[CacheTier] = None
        if self._uses_processes() or (cache_budget_bytes is not None
                                      and cache_budget_bytes <= 0):
            trial_dataset = self.dataset
        elif cache_budget_bytes is None:
            trial_dataset = self._cached_dataset(admit=False)
            if trial_dataset is not self.dataset:
                trial_tier = self._cache_tier
        else:
            chunk = locality_chunk if locality_chunk is not None \
                else self.params.locality_chunk
            trial_tier = CacheTier(int(cache_budget_bytes),
                                   chunk=max(1, chunk),
                                   num_items=len(self.dataset),
                                   item_nbytes=self._item_nbytes_mean())
            if epoch >= 1:     # a warm epoch finds the hot set resident
                self._prewarm_tier(trial_tier)
            trial_dataset = self.dataset.with_storage(
                CachedStorage(self.dataset.storage, trial_tier, admit=True))

        idx_iter = _take(trial_sampler.epoch_iter(epoch, locality_chunk),
                         num_batches)
        # snapshot BEFORE _pool(): worker threads start reading the moment
        # the pool is constructed, and their requests belong to this window.
        # Process pools read in the forked children — their parent-side
        # counters never move, so skip attribution rather than report 0.
        io_before = None if self._uses_processes() \
            else storage_io_counters(self.dataset.storage)
        tier_before = (trial_tier.hits, trial_tier.misses) \
            if trial_tier is not None else (0, 0)
        slow_before = self.cost_tracker.slow_batches
        fault_before = self.fault_stats.snapshot()
        q_before = len(self.quarantine)
        pool, monitor = self._pool(idx_iter, dataset=trial_dataset)
        total_bytes = 0
        n = 0

        def _counted(it):
            nonlocal total_bytes
            for b in it:
                total_bytes += batch_nbytes(b)
                yield b

        start = time.perf_counter()
        prev = start
        deltas: List[float] = []
        prefetcher = None
        try:
            it = _counted(iter(pool))
            if to_device:
                prefetcher = DevicePrefetcher(
                    it, depth=self.params.device_prefetch,
                    sharding=self.sharding,
                    transfer_threads=self.params.transfer_threads,
                    donate=self.params.donate_transfer,
                    staging_buffers=self.params.staging_buffers)
                it = iter(prefetcher)
            for _batch in it:
                n += 1
                now = time.perf_counter()
                deltas.append(now - prev)
                prev = now
        except MemoryOverflow:
            pool.shutdown()
            return TransferStats(float("inf"), n, total_bytes,
                                 overflowed=True,
                                 peak_loader_bytes=monitor.peak)
        elapsed = time.perf_counter() - start
        stats = TransferStats(elapsed, n, total_bytes,
                              peak_loader_bytes=monitor.peak,
                              batch_seconds=deltas)
        io_after = storage_io_counters(self.dataset.storage)
        if io_before is not None and io_after is not None:
            req = int(io_after["coalesced_requests"]
                      - io_before["coalesced_requests"])
            misses = ((io_after["reads"] - io_after["cache_hits"])
                      - (io_before["reads"] - io_before["cache_hits"]))
            stats.coalesced_requests = req
            stats.coalesced_run_len = misses / req if req else 0.0
            stats.cache_hits = int(io_after["cache_hits"]
                                   - io_before["cache_hits"])
            stats.cache_misses = int(io_after.get("cache_misses", 0)
                                     - io_before.get("cache_misses", 0))
        if trial_tier is not None:
            # tier hits never reach the storage counters; add them on top.
            # Tier MISSES do (they forward to the inner storage), so only
            # count them here when the storage kept no counters itself.
            stats.cache_hits += trial_tier.hits - tier_before[0]
            if io_before is None or io_after is None:
                stats.cache_misses += trial_tier.misses - tier_before[1]
        if prefetcher is not None:
            stats.staging_hit_rate = prefetcher.staging_hit_rate
        if self.cost_tracker.records:
            stats.sample_cost_mean_s = self.cost_tracker.mean()
            stats.sample_cost_p99_s = self.cost_tracker.p99()
            stats.slow_batches = self.cost_tracker.slow_batches - slow_before
        fault_after = self.fault_stats.snapshot()
        stats.read_retries = int(fault_after["read_retries"]
                                 - fault_before["read_retries"])
        stats.read_faults = int(fault_after["read_faults"]
                                - fault_before["read_faults"])
        stats.resubmits = int(fault_after["resubmits"]
                              - fault_before["resubmits"])
        stats.quarantined = len(self.quarantine) - q_before
        stats.degraded = self.fault_stats.degraded
        return stats


def _take(it, n):
    for i, x in enumerate(it):
        if i >= n:
            return
        yield x
