from repro.data.arena import ArenaBatch, SlabArena, SlabSlot  # noqa: F401
from repro.data.cache import (  # noqa: F401
    CachedStorage,
    CacheTier,
    plan_hot_chunks,
)
from repro.data.faults import (  # noqa: F401
    FaultPolicy,
    FaultStats,
    FaultyStorage,
    QuarantineLog,
    RetryPolicy,
    StorageFaultSpec,
    quarantine_complement,
)
from repro.data.dataset import (  # noqa: F401
    Dataset,
    default_collate,
    image_batch_transform,
    synthetic_image_dataset,
    token_dataset,
)
from repro.data.loader import (  # noqa: F401
    DataLoader,
    LoaderParams,
    LoaderStream,
    TransferStats,
)
from repro.data.sampler import SamplerState, ShardedSampler  # noqa: F401
from repro.data.storage import (  # noqa: F401
    ArrayStorage,
    BrownoutError,
    CorruptSampleError,
    FileStorage,
    LatencyStorage,
    SampleReadError,
    StorageProfile,
    TransientReadError,
    cifar10_profile,
    coalesce_runs,
    coco_profile,
)
