"""Slab arena: the recycled batch buffers behind the zero-copy fast path.

A ``SlabArena`` owns a small ring of *slots*.  Each slot is a dict of
per-field numpy slabs preallocated with the batch shapes/dtypes of the
pipeline's steady state, plus the slot's total ``nbytes`` computed exactly
once when the field spec is established.  Workers collate directly into a
slot (``Dataset.get_batch(..., out=slot.arrays)``), pass the slot token
through the queue, and the consumer's advance recycles it — so steady-state
delivery allocates no new per-field batch arrays at all.

Lifetime contract (see DESIGN.md §3): a zero-copy batch is valid until the
consumer requests the *next* batch.  A downstream stage that needs to hold
the buffers across that boundary (e.g. an async device transfer) calls
``ArenaBatch.detach()`` and later ``ArenaBatch.release()`` itself; the
producing pool then skips its automatic release.  Hot-swap drains deliver
every in-flight slot to the consumer, whose releases return them to the
(persistent, loader-owned) arena — nothing is leaked and nothing is lost.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Optional

import numpy as np


class SlabSlot:
    """One preallocated batch buffer: field name -> numpy slab."""

    __slots__ = ("arena", "arrays", "nbytes", "epoch")

    def __init__(self, arena: "SlabArena", arrays: Dict[str, np.ndarray]):
        self.arena = arena
        self.arrays = arrays
        self.nbytes = int(sum(np.asarray(v).nbytes for v in arrays.values()))
        self.epoch = arena._epoch     # spec generation this slot was cut for

    def release(self) -> None:
        self.arena._release(self)


class ArenaBatch(dict):
    """A batch whose field arrays are views of an arena slot.

    Behaves as a plain ``{field: ndarray}`` dict for every consumer.  The
    producing worker pool auto-releases the slot when the consumer advances,
    unless ``detach()`` transferred release responsibility downstream.
    """

    def __init__(self, slot: SlabSlot):
        super().__init__(slot.arrays)
        self.slot = slot
        self.nbytes = slot.nbytes
        self._detached = False
        self._released = False
        self._lock = threading.Lock()

    def detach(self) -> "ArenaBatch":
        """Take over release responsibility from the producing pool."""
        self._detached = True
        return self

    def copy_into(self, out: Dict[str, np.ndarray]) -> None:
        """Copy every field into matching preallocated buffers (the device
        edge's staging pool): after this returns, nothing downstream holds a
        view of the slot and the caller may ``release()`` immediately —
        decoupling the arena's lifetime from the device transfer."""
        for k, v in self.items():
            np.copyto(out[k], v)

    def release(self) -> None:
        with self._lock:
            if self._released:
                return
            self._released = True
        self.slot.release()

    def release_if_owned(self) -> None:
        """Called by the producing pool when the consumer advances."""
        if not self._detached:
            self.release()


def maybe_release(batch, *, owned_only: bool = True) -> None:
    """Recycle ``batch``'s slot if it is arena-backed (no-op otherwise)."""
    if isinstance(batch, ArenaBatch):
        if owned_only:
            batch.release_if_owned()
        else:
            batch.release()


class SlabArena:
    """Bounded pool of recycled batch slots.

    The field spec (shapes/dtypes) is discovered from the first batch the
    pipeline produces: that batch's freshly-allocated arrays are *adopted*
    as slot zero, and every further slot is cut to the same spec.  A
    mismatched batch (e.g. a ragged tail when ``drop_last=False``) simply
    bypasses the arena.

    ``capacity`` bounds live slots; ``acquire`` blocks (with a stop check,
    so draining workers never deadlock) until one is recycled.  ``resize``
    retargets capacity across a hot swap: surplus slots are dropped on
    release, missing ones are allocated on demand (counted as misses).
    """

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self._spec: Optional[Dict[str, tuple]] = None
        self._spec_nbytes = 0
        self._expected_leading: Optional[int] = None
        self._free: deque = deque()
        self._allocated = 0
        self._epoch = 0
        self._cond = threading.Condition()
        self.hits = 0
        self.misses = 0

    # ---- stats -------------------------------------------------------------
    @property
    def allocated(self) -> int:
        return self._allocated

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self._allocated - len(self._free)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def nbytes_in_use(self) -> int:
        """Bytes currently pinned by allocated slots.

        This is the number the cache tier deducts from its own budget so
        arena + cache share one memory budget without double-counting
        (DESIGN.md §7).  Asserts the arena itself never exceeds its
        capacity-implied byte budget — ``resize`` shrinks lazily, so a
        transient over-allocation is legal only while every surplus slot is
        in use (frees drain immediately, on resize and on release)."""
        with self._cond:
            assert (self._allocated <= self.capacity
                    or not self._free), \
                (self._allocated, self.capacity, len(self._free))
            return self._allocated * self._spec_nbytes

    # ---- spec --------------------------------------------------------------
    def matches(self, batch: Dict[str, np.ndarray]) -> bool:
        spec = {k: (np.asarray(v).shape, np.asarray(v).dtype)
                for k, v in batch.items()}
        return self._spec is None or spec == self._spec

    def adopt(self, batch: Dict[str, np.ndarray]) -> Optional[SlabSlot]:
        """Turn a freshly-allocated batch into a slot (establishes the spec
        on first use).  Returns None if the batch doesn't fit the spec —
        or, while the spec is unset, if its leading dim differs from the
        expected local batch (a ragged makeup chunk delivered right after
        a reshard must not pin the arena to the wrong shape)."""
        arrays = {k: np.asarray(v) for k, v in batch.items()}
        spec = {k: (v.shape, v.dtype) for k, v in arrays.items()}
        with self._cond:
            if self._spec is None:
                if self._expected_leading is not None and any(
                        v.ndim == 0 or v.shape[0] != self._expected_leading
                        for v in arrays.values()):
                    return None
                self._spec = spec
                self._spec_nbytes = int(
                    sum(v.nbytes for v in arrays.values()))
            elif spec != self._spec:
                return None
            self._allocated += 1
            self.misses += 1
        return SlabSlot(self, arrays)

    # ---- acquire / release -------------------------------------------------
    def acquire(self, stop: Optional[threading.Event] = None,
                poll_s: float = 0.5) -> Optional[SlabSlot]:
        """Pop a free slot, or allocate one while under capacity.

        Returns None when the spec is still unknown (caller produces a fresh
        batch and ``adopt``s it) or when ``stop`` was set while waiting.
        Waiters are woken by every release and by ``wake()`` (which pools
        call when setting their stop flag), so ``poll_s`` is only a backstop
        against a missed transition, not the reaction latency.
        """
        while True:
            with self._cond:
                if self._free:
                    self.hits += 1
                    return self._free.popleft()
                if self._spec is None:
                    return None
                if self._allocated < self.capacity:
                    self._allocated += 1
                    self.misses += 1
                    arrays = {k: np.empty(shape, dtype)
                              for k, (shape, dtype) in self._spec.items()}
                    return SlabSlot(self, arrays)
                self._cond.wait(poll_s)
            if stop is not None and stop.is_set():
                return None

    def wake(self) -> None:
        """Wake every blocked ``acquire`` so it re-checks its stop event —
        called by pools on stop/drain/error transitions."""
        with self._cond:
            self._cond.notify_all()

    def _release(self, slot: SlabSlot) -> None:
        with self._cond:
            if slot.epoch != self._epoch:
                self._allocated -= 1      # stale spec (respec): drop it
                return
            if self._allocated > self.capacity:
                self._allocated -= 1      # shrink toward the new capacity
                return
            self._free.append(slot)
            self._cond.notify()

    def resize(self, capacity: int) -> None:
        with self._cond:
            self.capacity = max(1, capacity)
            while self._allocated > self.capacity and self._free:
                self._free.pop()
                self._allocated -= 1

    def respec(self, *, expected_leading: Optional[int] = None) -> None:
        """Forget the slab spec — the batch shape is about to change (an
        elastic reshard resizes the local batch).  Free slots are dropped
        now; in-use slots are dropped when their holder releases them (the
        spec generation stamped on each slot tells stale from current), and
        the next batch produced re-establishes the spec at the new shape.
        ``expected_leading`` restricts which batch may do so (the new local
        batch size) — odd-shaped makeup chunks bypass the arena instead.
        """
        with self._cond:
            self._epoch += 1
            self._allocated -= len(self._free)
            self._free.clear()
            self._spec = None
            self._spec_nbytes = 0
            self._expected_leading = expected_leading
            self._cond.notify_all()
