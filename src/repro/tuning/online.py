"""Online retuning: tuning as a continuous background activity.

The paper tunes once, offline, before training starts.  Production hosts
drift: storage throughput sags under co-tenant load, CPU gets stolen, the
batch mix changes.  :class:`OnlineTuner` closes the loop:

  observe   — the trainer (or serving engine) feeds it one (data-wait,
              step-time) pair per step: the goodput signal.  The loader is
              healthy while its transfer time hides behind the model step;
              it is hurting goodput when the step stalls waiting for data.
  detect    — when the mean data-wait over a sliding window exceeds
              ``stall_fraction`` of the mean compute time (with warmup and
              a cooldown between retunes), drift is declared.
  re-search — a bounded strategy from the unified ``tune(...)`` layer runs
              against the live loader (trial cells measure on short
              side-channel epochs; the live stream keeps flowing).
  apply     — the winner is hot-swapped into the running DataLoader via
              ``apply_params`` (pool drained at a batch boundary, sampler
              state preserved, zero batches lost) and persisted in
              :class:`DPTCache` under the machine/dataset fingerprint so
              the next process on this host starts warm.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Dict, List, Optional

from repro.core.cache import DPTCache
from repro.core.dpt import DPTConfig, DPTResult
from repro.core.monitor import MemoryOverflow
from repro.data.loader import DataLoader, LoaderParams
from repro.tuning.base import tune
from repro.utils.fingerprint import machine_fingerprint


@dataclasses.dataclass
class OnlineTunerConfig:
    stall_fraction: float = 0.35     # data-wait / compute-time drift trigger
    window: int = 8                  # steps in the drift window
    warmup_steps: int = 4            # observations before drift can fire
    cooldown_steps: int = 16         # min steps between retunes
    # Measurement budget per trial cell.  Must comfortably exceed the max
    # worker count under consideration: with budget <= nworker every config
    # finishes in one parallel wave and all cells measure identically
    # (pipeline fill, not steady-state rate).  ~3x max workers is a good
    # floor for wall-clock evaluators.
    retune_budget_batches: int = 8
    max_prefetch: int = 4
    strategy: str = "hillclimb"      # bounded re-search policy
    max_search_steps: int = 12       # hillclimb step bound
    min_improvement: float = 0.05    # swap only if >=5% faster than current
    max_backoff: int = 8             # cooldown multiplier cap on no-win
    num_cpu_cores: Optional[int] = None   # override DPTConfig.resolve()
    num_devices: Optional[int] = None


class OnlineTuner:
    """Watches goodput and retunes a live DataLoader when it drifts."""

    def __init__(self, loader: DataLoader, *,
                 config: OnlineTunerConfig = OnlineTunerConfig(),
                 evaluator=None, cache: Optional[DPTCache] = None,
                 machine_fp: Optional[str] = None,
                 dataset_fp: Optional[str] = None):
        self.loader = loader
        self.cfg = config
        if evaluator is None:
            from repro.core.evaluators import LoaderEvaluator
            evaluator = LoaderEvaluator(loader, to_device=True)
        self.evaluator = evaluator
        self.cache = cache
        self.machine_fp = machine_fp or machine_fingerprint()
        self.dataset_fp = dataset_fp or loader.dataset.fingerprint()
        self._data_s: deque = deque(maxlen=config.window)
        self._compute_s: deque = deque(maxlen=config.window)
        self._steps = 0
        self._last_retune_step = -config.cooldown_steps
        self._backoff = 1            # doubles when a re-search finds no win
        self.retunes = 0
        self.history: List[Dict[str, Any]] = []

    # ---- the per-step goodput signal ---------------------------------------
    def observe(self, *, data_s: float, step_s: float
                ) -> Optional[LoaderParams]:
        """Feed one step's data-wait and total step wall time.

        Returns the newly applied LoaderParams when this observation
        triggered a retune + hot-swap, else None.
        """
        self._steps += 1
        self._data_s.append(max(0.0, data_s))
        self._compute_s.append(max(1e-9, step_s - data_s))
        if self._steps < self.cfg.warmup_steps:
            return None
        cooldown = self.cfg.cooldown_steps * self._backoff
        if self._steps - self._last_retune_step < cooldown:
            return None
        if len(self._data_s) < self._data_s.maxlen:
            return None
        if not self.drifted:
            return None
        return self.force_retune(reason="goodput-drift")

    @property
    def stall_ratio(self) -> float:
        """Mean data-wait over mean compute time in the current window."""
        if not self._compute_s:
            return 0.0
        return (sum(self._data_s) / len(self._data_s)) \
            / (sum(self._compute_s) / len(self._compute_s))

    @property
    def drifted(self) -> bool:
        return self.stall_ratio > self.cfg.stall_fraction

    # ---- bounded re-search + hot swap --------------------------------------
    def _search(self) -> Optional[DPTResult]:
        cfg = DPTConfig(num_cpu_cores=self.cfg.num_cpu_cores,
                        num_devices=self.cfg.num_devices,
                        max_prefetch=self.cfg.max_prefetch,
                        num_batches=self.cfg.retune_budget_batches)
        kwargs: Dict[str, Any] = {}
        if self.cfg.strategy == "hillclimb":
            _, G = cfg.resolve()
            kwargs = {"start": (max(G, self.loader.params.num_workers),
                                self.loader.params.prefetch_factor),
                      "max_steps": self.cfg.max_search_steps}
        elif self.cfg.strategy == "grid":
            kwargs = {"measure_default": False}
        try:
            return tune(evaluator=self.evaluator, strategy=self.cfg.strategy,
                        config=cfg, **kwargs)
        except MemoryOverflow:
            return None

    def force_retune(self, *, reason: str = "forced"
                     ) -> Optional[LoaderParams]:
        """Run the bounded re-search now and hot-swap the winner in.

        Also the entry point for external drift signals (e.g. the serving
        frontend's batch-mix monitor).
        """
        orig = self.loader.params
        t0 = time.perf_counter()
        try:
            result = self._search()
        finally:
            # trial measurements mutate loader.params via with_params;
            # restore even on unexpected evaluator errors so a live stream
            # never rebuilds on trial params
            self.loader.with_params(orig)
        self._last_retune_step = self._steps
        self._data_s.clear()
        self._compute_s.clear()
        if result is None or not math.isfinite(result.optimal_time):
            self._backoff = min(self.cfg.max_backoff, self._backoff * 2)
            return None
        # anti-churn: only swap when the winner beats the CURRENT config's
        # own measured time by min_improvement.  The reference cell is the
        # hillclimb's first trial (its start — the current config snapped
        # onto the search lattice); for other strategies, the trial at the
        # current cell if the sweep covered it.  A no-win search doubles
        # the cooldown — if the loader is simply the bottleneck at its
        # optimum, re-search cannot help and should get rarer.
        if self.cfg.strategy == "hillclimb" and result.trials:
            ref = result.trials[0]
        else:
            ref = next((t for t in result.trials
                        if (t.nworker, t.nprefetch)
                        == (orig.num_workers, orig.prefetch_factor)), None)
        current = ref.seconds if ref is not None else None
        same = (result.nworker, result.nprefetch) \
            == (orig.num_workers, orig.prefetch_factor)
        if ref is not None:
            same = same or (result.nworker, result.nprefetch) \
                == (ref.nworker, ref.nprefetch)
        if same or (current is not None and result.optimal_time
                    > (1.0 - self.cfg.min_improvement) * current):
            self._backoff = min(self.cfg.max_backoff, self._backoff * 2)
            self.history.append({
                "step": self._steps, "reason": reason, "outcome": "kept",
                "params": (orig.num_workers, orig.prefetch_factor),
                "optimal_time": result.optimal_time,
                "measurements": len(result.trials),
                "search_s": time.perf_counter() - t0,
            })
            return None
        self._backoff = 1
        params = orig.replace(num_workers=result.nworker,
                              prefetch_factor=result.nprefetch)
        self.loader.apply_params(params)
        if self.cache is not None:
            self.cache.put(self.machine_fp, self.dataset_fp,
                           self.loader.global_batch, result)
        self.retunes += 1
        self.history.append({
            "step": self._steps, "reason": reason, "outcome": "applied",
            "params": (result.nworker, result.nprefetch),
            "optimal_time": result.optimal_time,
            "measurements": len(result.trials),
            "search_s": time.perf_counter() - t0,
        })
        return params
