"""Online retuning: tuning as a continuous background activity.

The paper tunes once, offline, before training starts.  Production hosts
drift: storage throughput sags under co-tenant load, CPU gets stolen, the
batch mix changes.  The loop is split into three separable components so
the same machinery serves a single host (:class:`OnlineTuner`) and a
coordinated fleet (:mod:`repro.tuning.fleet`, where observe stays on the
host and decide moves to the coordinator):

  observe   — :class:`GoodputMonitor`: the trainer (or serving engine)
              feeds it one (data-wait, step-time) pair per step.  The
              loader is healthy while its transfer time hides behind the
              model step; it is hurting goodput when the step stalls
              waiting for data.
  decide    — :class:`RetunePolicy`: warmup/cooldown/backoff bookkeeping
              plus the win test.  Drift is declared when the mean
              data-wait over the window exceeds ``stall_fraction`` of the
              mean compute time; a search winner is accepted only when it
              beats the current config by a variance-aware Welch test
              over per-batch times (falling back to the relative
              ``min_improvement`` threshold when the evaluator measured
              no per-batch samples).
  act       — :class:`RetuneExecutor`: runs a bounded strategy from the
              unified ``tune(...)`` layer against the live loader (trial
              cells measure on short side-channel epochs; the live stream
              keeps flowing), hot-swaps the winner in via
              ``apply_params`` (pool drained at a batch boundary, sampler
              state preserved, zero batches lost) and persists it in
              :class:`DPTCache` under the machine/dataset fingerprint so
              the next process on this host starts warm.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from repro.core.cache import DPTCache
from repro.core.dpt import DPTConfig, DPTResult, Trial
from repro.core.monitor import MemoryOverflow
from repro.data.loader import DataLoader, LoaderParams
from repro.tuning.base import (adaptive_budget, steady_samples, tune,
                               welch_wins)
from repro.utils.fingerprint import machine_fingerprint


@dataclasses.dataclass
class OnlineTunerConfig:
    stall_fraction: float = 0.35     # data-wait / compute-time drift trigger
    window: int = 8                  # steps in the drift window
    warmup_steps: int = 4            # observations before drift can fire
    cooldown_steps: int = 16         # min steps between retunes
    # Measurement budget per trial cell.  None derives it adaptively as
    # >= 3x the deepest worker rung in the search space (see
    # tuning.base.adaptive_budget): with budget <= nWorker every config
    # finishes in one parallel wave and all cells measure identically
    # (pipeline fill, not steady-state rate).
    retune_budget_batches: Optional[int] = None
    max_prefetch: int = 4
    strategy: str = "hillclimb"      # bounded re-search policy
    max_search_steps: int = 12       # hillclimb step bound
    min_improvement: float = 0.05    # fallback win threshold (no samples)
    max_backoff: int = 8             # cooldown multiplier cap on no-win
    num_cpu_cores: Optional[int] = None   # override DPTConfig.resolve()
    num_devices: Optional[int] = None
    # online locality axis (DESIGN.md §6): candidate sampler chunk sizes a
    # retune may propose.  None keeps retunes on (workers, prefetch) — the
    # startup grid owns the knob.  When set, each retune prices the
    # candidates at the winning cell through the measurement-only override
    # and a significant winner rides the same hot swap (epoch-latched).
    locality_chunks: Optional[Tuple[int, ...]] = None
    # online cache axis (DESIGN.md §7): candidate cross-epoch cache budgets
    # a retune may propose.  Same ownership split as locality: None leaves
    # the knob to the startup grid.  Candidates are priced at a WARM epoch
    # through throwaway measurement tiers (the live tier is never polluted)
    # and a winner resizes the live tier in place via apply_params.
    cache_budgets: Optional[Tuple[int, ...]] = None
    # online dual-lane axis (DESIGN.md §9): candidate slow-lane widths a
    # retune may propose.  Same ownership split; candidates are priced
    # through the measurement-only override while the live cost tracker
    # keeps learning through the trials.
    slow_lanes: Optional[Tuple[int, ...]] = None
    # retune trigger on the per-item cost tail (io_counters'
    # ``sample_cost_tail_ratio``: p99 over median of the tracked per-item
    # cost estimates, ~1 uniform, large under a heavy tail).  0 disables;
    # only armed when ``slow_lanes`` is set — the tail signal exists to
    # resolve the lane axis, stalls still fire the goodput trigger.
    tail_ratio_trigger: float = 0.0
    # retune trigger on the fault plane (DESIGN.md §10): io_counters'
    # windowed ``fault_rate``.  0 disables.  Fires on the way IN (the
    # storage is browning out — a shallower config wastes less work on
    # reads that will be retried) and once on the way OUT (degraded mode
    # healed — re-search for the healthy optimum the degraded window
    # may have walked away from).
    fault_rate_trigger: float = 0.0


class GoodputMonitor:
    """Observe: the per-step goodput signal, windowed.

    One ``observe(data_s, step_s)`` call per training/serving step.  The
    stall ratio (mean data-wait over mean compute) is the drift signal;
    ``batch_seconds`` exposes the raw window for fleet reports.
    """

    def __init__(self, window: int = 8):
        self._data_s: deque = deque(maxlen=window)
        self._compute_s: deque = deque(maxlen=window)
        self.steps = 0
        # latest per-item cost tail ratio (p99/median) pushed from the
        # loader's cost tracker via note_tail(); 0 = no signal yet
        self.tail_ratio = 0.0
        # fault-plane signal (DESIGN.md §10), pushed via note_faults()
        self.fault_rate = 0.0
        self.degraded = False
        self.fault_healed = False   # one-shot: degraded -> healthy edge

    def observe(self, *, data_s: float, step_s: float) -> None:
        self.steps += 1
        self._data_s.append(max(0.0, data_s))
        self._compute_s.append(max(1e-9, step_s - data_s))

    def note_tail(self, ratio: float) -> None:
        """Push the loader's per-item cost tail ratio (DESIGN.md §9)."""
        self.tail_ratio = max(0.0, ratio)

    def note_faults(self, rate: float, degraded: bool) -> None:
        """Push the loader's windowed fault rate + degraded flag
        (DESIGN.md §10).  The degraded→healthy transition latches
        ``fault_healed`` so the heal fires one retune even though the
        rate is back under the trigger by then."""
        if self.degraded and not degraded:
            self.fault_healed = True
        self.fault_rate = max(0.0, rate)
        self.degraded = bool(degraded)

    @property
    def full(self) -> bool:
        return len(self._data_s) == self._data_s.maxlen

    @property
    def stall_ratio(self) -> float:
        """Mean data-wait over mean compute time in the current window."""
        if not self._compute_s:
            return 0.0
        return (sum(self._data_s) / len(self._data_s)) \
            / (sum(self._compute_s) / len(self._compute_s))

    @property
    def steps_per_s(self) -> float:
        """Goodput over the window (steps per wall second)."""
        total = sum(self._data_s) + sum(self._compute_s)
        return len(self._data_s) / total if total > 0 else 0.0

    @property
    def batch_seconds(self) -> List[float]:
        """Per-step wall times in the window (data wait + compute)."""
        return [d + c for d, c in zip(self._data_s, self._compute_s)]

    def reset(self) -> None:
        self._data_s.clear()
        self._compute_s.clear()
        self.fault_healed = False


class RetunePolicy:
    """Decide: when a re-search may run and whether its winner is real.

    Owns the warmup/cooldown/backoff bookkeeping and the win test; holds
    no reference to the loader or evaluator, so a coordinator can run the
    same policy over aggregated fleet signals.
    """

    def __init__(self, cfg: OnlineTunerConfig):
        self.cfg = cfg
        self._last_retune_step = -cfg.cooldown_steps
        self._backoff = 1            # doubles when a re-search finds no win

    def drifted(self, monitor: GoodputMonitor) -> bool:
        if monitor.stall_ratio > self.cfg.stall_fraction:
            return True
        # fault drift (DESIGN.md §10): the storage is failing hot (rate
        # over trigger) or just healed from degraded mode (one-shot edge)
        if self.cfg.fault_rate_trigger > 0.0 and (
                monitor.fault_rate > self.cfg.fault_rate_trigger
                or monitor.fault_healed):
            return True
        # tail drift: a heavy per-item cost tail is drift even before it
        # shows as a mean stall — only armed when the lane axis exists
        return bool(self.cfg.slow_lanes
                    and self.cfg.tail_ratio_trigger > 0.0
                    and monitor.tail_ratio > self.cfg.tail_ratio_trigger)

    def should_retune(self, monitor: GoodputMonitor) -> bool:
        if monitor.steps < self.cfg.warmup_steps:
            return False
        cooldown = self.cfg.cooldown_steps * self._backoff
        if monitor.steps - self._last_retune_step < cooldown:
            return False
        if not monitor.full:
            return False
        return self.drifted(monitor)

    def note_searched(self, step: int) -> None:
        self._last_retune_step = step

    def record_outcome(self, won: bool) -> None:
        """A no-win search doubles the cooldown — if the loader is simply
        the bottleneck at its optimum, re-search cannot help and should
        get rarer.  A win resets the backoff."""
        self._backoff = 1 if won else min(self.cfg.max_backoff,
                                          self._backoff * 2)

    # ---- the win test ------------------------------------------------------
    @staticmethod
    def _find_trial(result: DPTResult, cell: Tuple[int, int],
                    strategy: str) -> Optional[Trial]:
        if strategy == "hillclimb" and result.trials:
            # the hillclimb's first trial is its start: the current config
            # snapped onto the search lattice — the improvement reference
            # even when the exact current cell is off-lattice
            return result.trials[0]
        return next((t for t in result.trials
                     if (t.nworker, t.nprefetch) == cell), None)

    def is_win(self, result: DPTResult, current: LoaderParams) -> bool:
        """Anti-churn: only swap when the winner beats the CURRENT config's
        own measured cell.

        With per-batch samples on both cells the comparison is a Welch
        test (variance-aware: noisy measurements need a bigger gap);
        without samples it falls back to the relative ``min_improvement``
        threshold on the cell means.
        """
        cur_cell = (current.num_workers, current.prefetch_factor)
        ref = self._find_trial(result, cur_cell, self.cfg.strategy)
        win_cell = (result.nworker, result.nprefetch)
        if win_cell == cur_cell:
            return False
        if ref is not None and win_cell == (ref.nworker, ref.nprefetch):
            return False
        if ref is None:
            return True                      # nothing measured to defend
        winner = next((t for t in result.trials
                       if (t.nworker, t.nprefetch) == win_cell), None)
        # drop each cell's pipeline-fill prefix before the Welch test
        # (see tuning.base.steady_samples)
        ref_samples = steady_samples(ref.batch_seconds)
        win_samples = steady_samples(winner.batch_seconds) if winner else []
        if len(ref_samples) >= 2 and len(win_samples) >= 2:
            return welch_wins(ref_samples, win_samples)
        return result.optimal_time \
            <= (1.0 - self.cfg.min_improvement) * ref.seconds


class RetuneExecutor:
    """Act: bounded re-search against the live loader + hot swap + cache."""

    def __init__(self, loader: DataLoader, evaluator,
                 cfg: OnlineTunerConfig, *, cache: Optional[DPTCache] = None,
                 machine_fp: Optional[str] = None,
                 dataset_fp: Optional[str] = None):
        self.loader = loader
        self.evaluator = evaluator
        self.cfg = cfg
        self.cache = cache
        self.machine_fp = machine_fp or machine_fingerprint()
        self.dataset_fp = dataset_fp or loader.dataset.fingerprint()

    def search_config(self) -> DPTConfig:
        cfg = DPTConfig(num_cpu_cores=self.cfg.num_cpu_cores,
                        num_devices=self.cfg.num_devices,
                        max_prefetch=self.cfg.max_prefetch)
        return dataclasses.replace(cfg, num_batches=adaptive_budget(
            cfg, self.cfg.retune_budget_batches))

    def search(self) -> Optional[DPTResult]:
        """Run the bounded strategy; the loader's params are restored even
        on unexpected evaluator errors so a live stream never rebuilds on
        trial params (trial measurements mutate loader.params via
        with_params)."""
        orig = self.loader.params
        cfg = self.search_config()
        kwargs: Dict[str, Any] = {}
        if self.cfg.strategy == "hillclimb":
            _, G = cfg.resolve()
            kwargs = {"start": (max(G, orig.num_workers),
                                orig.prefetch_factor),
                      "max_steps": self.cfg.max_search_steps}
        elif self.cfg.strategy == "grid":
            kwargs = {"measure_default": False}
        try:
            return tune(evaluator=self.evaluator, strategy=self.cfg.strategy,
                        config=cfg, **kwargs)
        except MemoryOverflow:
            return None
        finally:
            self.loader.with_params(orig)

    def sweep_locality(self, nworker: int, nprefetch: int
                       ) -> Tuple[Optional[int], List[Trial]]:
        """Price the configured chunk candidates at one cell.

        Returns ``(winner, trials)``: the significant winning chunk (None
        = keep the current one) plus the sweep's trials, so the caller
        can fold them into the retune's DPTResult (the cache reads them
        to tell a searched axis from a blind one).  Trials run through
        the measurement-only override, so the live epoch schedule is
        never perturbed; loader params are restored afterwards.
        """
        if not self.cfg.locality_chunks:
            return None, []
        from repro.tuning.locality import locality_win, sweep_locality
        orig = self.loader.params
        cfg = self.search_config()
        try:
            trials = sweep_locality(
                self.evaluator, nworker=nworker, nprefetch=nprefetch,
                chunks=self.cfg.locality_chunks,
                current_chunk=orig.locality_chunk,
                num_batches=cfg.num_batches, epoch=cfg.epoch)
        finally:
            self.loader.with_params(orig)
        win = locality_win(trials, orig.locality_chunk,
                           min_improvement=self.cfg.min_improvement)
        return win, list(trials.values())

    def sweep_cache(self, nworker: int, nprefetch: int
                    ) -> Tuple[Optional[int], List[Trial]]:
        """Price the configured cache budgets at one cell (DESIGN.md §7).

        Same contract as :meth:`sweep_locality`, one difference: budgets
        are measured at a WARM epoch (max(1, cfg.epoch)) because a
        cross-epoch cache only pays off once it has something to serve —
        cold pricing would always pick 0.  Trials run on throwaway tiers
        (the evaluator's measurement-only override), so the live tier's
        contents are never perturbed; loader params are restored.
        """
        if not self.cfg.cache_budgets:
            return None, []
        from repro.tuning.locality import cache_win, sweep_cache
        orig = self.loader.params
        cfg = self.search_config()
        try:
            trials = sweep_cache(
                self.evaluator, nworker=nworker, nprefetch=nprefetch,
                budgets=self.cfg.cache_budgets,
                current_budget=orig.cache_budget_bytes,
                num_batches=cfg.num_batches, epoch=max(1, cfg.epoch))
        finally:
            self.loader.with_params(orig)
        win = cache_win(trials, orig.cache_budget_bytes,
                        min_improvement=self.cfg.min_improvement)
        return win, list(trials.values())

    def sweep_slow_lane(self, nworker: int, nprefetch: int
                        ) -> Tuple[Optional[int], List[Trial]]:
        """Price the configured slow-lane widths at one cell (DESIGN.md
        §9).  Same contract as :meth:`sweep_locality`; candidates go
        through the measurement-only override (the live pool's lane split
        is untouched) and the live cost tracker keeps learning through
        the trial decodes, so the sweep prices routing, not a cold lane.
        """
        if not self.cfg.slow_lanes:
            return None, []
        from repro.tuning.locality import slow_lane_win, sweep_slow_lanes
        orig = self.loader.params
        cfg = self.search_config()
        try:
            trials = sweep_slow_lanes(
                self.evaluator, nworker=nworker, nprefetch=nprefetch,
                lanes=self.cfg.slow_lanes,
                current_lanes=orig.slow_lane_workers,
                num_batches=cfg.num_batches, epoch=cfg.epoch)
        finally:
            self.loader.with_params(orig)
        win = slow_lane_win(trials, orig.slow_lane_workers,
                            min_improvement=self.cfg.min_improvement)
        return win, list(trials.values())

    def apply(self, result: DPTResult,
              params: Optional[LoaderParams] = None) -> LoaderParams:
        """Hot-swap the winner into the live stream and persist it.

        ``params`` is the full target (a locality-aware retune may keep
        the current cell and change only the chunk); None applies the
        result's (nworker, nprefetch) over the current params.
        """
        if params is None:
            params = self.loader.params.replace(
                num_workers=result.nworker,
                prefetch_factor=result.nprefetch)
        self.loader.apply_params(params)
        if self.cache is not None:
            # cache what was APPLIED, not the raw argmin (the policy may
            # have kept the current cell and taken only the chunk) — and
            # pair the cell with ITS OWN measured time, not the rejected
            # argmin cell's (the locality sweep measured the applied
            # combination when the cell was kept)
            opt = result.optimal_time
            applied_cell = (params.num_workers, params.prefetch_factor)
            # an exact (cell, chunk) trial exists whenever the locality
            # sweep changed the chunk (it measured every candidate at
            # the applied cell) or the policy kept the current cell
            t = next((t for t in result.trials
                      if (t.nworker, t.nprefetch) == applied_cell
                      and t.locality_chunk == params.locality_chunk
                      and math.isfinite(t.seconds)), None)
            if t is not None and (
                    applied_cell != (result.nworker, result.nprefetch)
                    or params.locality_chunk != result.locality_chunk):
                opt = t.seconds
            cached = dataclasses.replace(
                result, nworker=params.num_workers,
                nprefetch=params.prefetch_factor,
                locality_chunk=params.locality_chunk,
                cache_budget_bytes=params.cache_budget_bytes,
                slow_lane_workers=params.slow_lane_workers,
                optimal_time=opt)
            self.cache.put(self.machine_fp, self.dataset_fp,
                           self.loader.global_batch, cached)
        return params


class OnlineTuner:
    """Watches goodput and retunes a live DataLoader when it drifts.

    A thin composition of the observe/decide/act components above; the
    fleet control plane recomposes the same parts with decide living in
    the coordinator.
    """

    def __init__(self, loader: DataLoader, *,
                 config: OnlineTunerConfig = OnlineTunerConfig(),
                 evaluator=None, cache: Optional[DPTCache] = None,
                 machine_fp: Optional[str] = None,
                 dataset_fp: Optional[str] = None):
        self.loader = loader
        self.cfg = config
        if evaluator is None:
            from repro.core.evaluators import LoaderEvaluator
            evaluator = LoaderEvaluator(loader, to_device=True)
        self.evaluator = evaluator
        self.monitor = GoodputMonitor(window=config.window)
        self.policy = RetunePolicy(config)
        self.executor = RetuneExecutor(loader, evaluator, config,
                                       cache=cache, machine_fp=machine_fp,
                                       dataset_fp=dataset_fp)
        self.retunes = 0
        self.history: List[Dict[str, Any]] = []

    # back-compat accessors (pre-split callers and tests use these)
    @property
    def cache(self):
        return self.executor.cache

    @property
    def machine_fp(self):
        return self.executor.machine_fp

    @property
    def dataset_fp(self):
        return self.executor.dataset_fp

    @property
    def stall_ratio(self) -> float:
        return self.monitor.stall_ratio

    @property
    def drifted(self) -> bool:
        return self.policy.drifted(self.monitor)

    # ---- the per-step goodput signal ---------------------------------------
    def observe(self, *, data_s: float, step_s: float
                ) -> Optional[LoaderParams]:
        """Feed one step's data-wait and total step wall time.

        Returns the newly applied LoaderParams when this observation
        triggered a retune + hot-swap, else None.
        """
        self.monitor.observe(data_s=data_s, step_s=step_s)
        # feed the loader-side signals once per window (io_counters takes
        # locks; no need to pay them every step)
        want_tail = self.cfg.slow_lanes and self.cfg.tail_ratio_trigger > 0.0
        want_fault = self.cfg.fault_rate_trigger > 0.0
        if (want_tail or want_fault) \
                and self.monitor.steps % self.cfg.window == 0:
            io = self.loader.io_counters()
            if want_tail and io and "sample_cost_tail_ratio" in io:
                self.monitor.note_tail(io["sample_cost_tail_ratio"])
            if want_fault:
                # absent keys mean a quiet fault plane — feed zeros so a
                # healed loader's monitor sees the edge
                self.monitor.note_faults(
                    (io or {}).get("fault_rate", 0.0),
                    bool((io or {}).get("degraded", 0.0)))
        if not self.policy.should_retune(self.monitor):
            return None
        if self.monitor.stall_ratio > self.cfg.stall_fraction:
            reason = "goodput-drift"
        elif want_fault and self.monitor.fault_healed:
            reason = "fault-heal"
        elif want_fault and self.monitor.fault_rate \
                > self.cfg.fault_rate_trigger:
            reason = "fault-drift"
        else:
            reason = "cost-tail-drift"
        return self.force_retune(reason=reason)

    # ---- bounded re-search + hot swap --------------------------------------
    def force_retune(self, *, reason: str = "forced"
                     ) -> Optional[LoaderParams]:
        """Run the bounded re-search now and hot-swap the winner in.

        Also the entry point for external drift signals (e.g. the serving
        frontend's batch-mix monitor).
        """
        orig = self.loader.params
        t0 = time.perf_counter()
        result = self.executor.search()
        self.policy.note_searched(self.monitor.steps)
        self.monitor.reset()
        if result is None or not math.isfinite(result.optimal_time):
            self.policy.record_outcome(won=False)
            return None
        won = self.policy.is_win(result, orig)
        # the online locality axis (DESIGN.md §6): price chunk candidates
        # at the cell the fleet will actually run — the search winner if
        # it won, else the current cell — and let a significant chunk win
        # ride the same hot swap (epoch-latched by the sampler)
        cell = (result.nworker, result.nprefetch) if won \
            else (orig.num_workers, orig.prefetch_factor)
        chunk_win, chunk_trials = self.executor.sweep_locality(*cell)
        result.trials.extend(chunk_trials)
        # the online cache axis (DESIGN.md §7): price budget candidates at
        # the same cell — a winner resizes the live tier in place via the
        # same hot swap (the tier survives apply_params)
        budget_win, budget_trials = self.executor.sweep_cache(*cell)
        result.trials.extend(budget_trials)
        # the online dual-lane axis (DESIGN.md §9): price lane widths at
        # the same cell — a winner re-splits the pool via the same hot
        # swap (the cost tracker is loader-owned and survives the swap)
        lane_win, lane_trials = self.executor.sweep_slow_lane(*cell)
        result.trials.extend(lane_trials)
        self.policy.record_outcome(won=won or chunk_win is not None
                                   or budget_win is not None
                                   or lane_win is not None)
        if not won and chunk_win is None and budget_win is None \
                and lane_win is None:
            self.history.append({
                "step": self.monitor.steps, "reason": reason,
                "outcome": "kept",
                "params": (orig.num_workers, orig.prefetch_factor),
                "locality_chunk": orig.locality_chunk,
                "cache_budget_bytes": orig.cache_budget_bytes,
                "slow_lane_workers": orig.slow_lane_workers,
                "optimal_time": result.optimal_time,
                "measurements": len(result.trials),
                "search_s": time.perf_counter() - t0,
            })
            return None
        params = orig if not won else orig.replace(
            num_workers=result.nworker, prefetch_factor=result.nprefetch)
        if chunk_win is not None:
            params = params.replace(locality_chunk=chunk_win)
        if budget_win is not None:
            params = params.replace(cache_budget_bytes=budget_win)
        if lane_win is not None:
            params = params.replace(slow_lane_workers=lane_win)
        params = self.executor.apply(result, params)
        self.retunes += 1
        self.history.append({
            "step": self.monitor.steps, "reason": reason,
            "outcome": "applied",
            "params": (params.num_workers, params.prefetch_factor),
            "locality_chunk": params.locality_chunk,
            "cache_budget_bytes": params.cache_budget_bytes,
            "slow_lane_workers": params.slow_lane_workers,
            "optimal_time": result.optimal_time,
            "measurements": len(result.trials),
            "search_s": time.perf_counter() - t0,
        })
        return params
