"""The online locality loop (DESIGN.md §6).

PR 4 made ``locality_chunk`` the startup grid's third axis; this module
makes it a first-class *online* knob, closed at two speeds:

* **Retune-time sweep** (:func:`sweep_locality` + :func:`locality_win`):
  when an online re-search runs anyway, candidate chunk sizes are priced
  at the winning (nWorker, nPrefetch) cell through the measurement-only
  evaluator override (trials never touch the live epoch schedule), and a
  significant winner rides the same hot swap — latched at the next epoch
  boundary by ``ShardedSampler.set_locality``.

* **Counter-driven resize** (:class:`AdaptiveLocalityController`): the
  live pipeline already surfaces its achieved coalesced run length
  (``DataLoader.io_counters``).  When the observed run length falls well
  below the active chunk — the cache warmed up, the storage topology
  changed, a reshard shrank per-host slices — chunking is buying nothing
  at its current size, and the controller proposes a resize *without* a
  search: shrink toward what the storage actually achieves.  Proposals
  apply through ``apply_params`` (single host) or route to the fleet
  coordinator (``on_propose``), because a sharded fleet may only change
  locality uniformly.

Who owns the knob when: the startup grid owns the *initial* chunk (it
can afford to measure the full axis cold); the retune sweep owns drift
that a measurement can resolve (storage got slower/faster); the adaptive
controller owns the fast path down (observed runs collapsed) — it only
ever shrinks, so a wrong proposal costs locality, never correctness, and
the next retune sweep can climb back up.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.dpt import Trial
from repro.core.monitor import MemoryOverflow
from repro.tuning.base import steady_samples, welch_wins


def sweep_locality(evaluator, *, nworker: int, nprefetch: int,
                   chunks: Sequence[int], current_chunk: int,
                   num_batches: int, epoch: int = 0) -> Dict[int, Trial]:
    """Price candidate ``locality_chunk`` values at one (worker, prefetch)
    cell through the evaluator's measurement-only override.

    The current chunk is always measured too (it is the reference the win
    test defends), every candidate at the SAME cell — so the comparison
    isolates the locality axis.  Overflowed cells score ``inf``.
    """
    trials: Dict[int, Trial] = {}
    for chunk in dict.fromkeys([max(0, int(current_chunk)),
                                *(max(0, int(c)) for c in chunks)]):
        try:
            stats = evaluator(nworker, nprefetch, num_batches=num_batches,
                              epoch=epoch, locality_chunk=chunk)
            if stats.overflowed:
                raise MemoryOverflow("overflowed")
            trials[chunk] = Trial(
                nworker, nprefetch, stats.seconds,
                peak_bytes=stats.peak_loader_bytes,
                batch_seconds=getattr(stats, "batch_seconds", None),
                locality_chunk=chunk)
        except MemoryOverflow:
            trials[chunk] = Trial(nworker, nprefetch, math.inf,
                                  overflowed=True, locality_chunk=chunk)
    return trials


def locality_win(trials: Dict[int, Trial], current_chunk: int, *,
                 min_improvement: float = 0.05) -> Optional[int]:
    """The locality analogue of ``RetunePolicy.is_win``: the argmin chunk
    must beat the CURRENT chunk's own measured trial — by a Welch test
    over per-batch times when both sides carry samples, else by the
    relative ``min_improvement`` threshold.  Returns the winning chunk,
    or None (keep the current one)."""
    current_chunk = max(0, int(current_chunk))
    finite = {c: t for c, t in trials.items() if math.isfinite(t.seconds)}
    if not finite:
        return None
    best = min(finite, key=lambda c: finite[c].seconds)
    ref = trials.get(current_chunk)
    if best == current_chunk:
        return None
    if ref is None or not math.isfinite(ref.seconds):
        return best                       # nothing measured to defend
    ref_s = steady_samples(ref.batch_seconds)
    win_s = steady_samples(finite[best].batch_seconds)
    if len(ref_s) >= 2 and len(win_s) >= 2:
        return best if welch_wins(ref_s, win_s) else None
    if finite[best].seconds <= (1.0 - min_improvement) * ref.seconds:
        return best
    return None


def sweep_cache(evaluator, *, nworker: int, nprefetch: int,
                budgets: Sequence[int], current_budget: int,
                num_batches: int, epoch: int = 1) -> Dict[int, Trial]:
    """Price candidate ``cache_budget_bytes`` values at one (worker,
    prefetch) cell — the cache analogue of :func:`sweep_locality`.

    Measured at a WARM epoch by default: a cross-epoch cache only pays off
    from epoch 1 on, so pricing it cold would always pick 0.  Candidates
    go through the evaluator's measurement-only override (throwaway tiers;
    the live tier is never polluted).
    """
    trials: Dict[int, Trial] = {}
    for budget in dict.fromkeys([max(0, int(current_budget)),
                                 *(max(0, int(b)) for b in budgets)]):
        try:
            stats = evaluator(nworker, nprefetch, num_batches=num_batches,
                              epoch=epoch, cache_budget_bytes=budget)
            if stats.overflowed:
                raise MemoryOverflow("overflowed")
            trials[budget] = Trial(
                nworker, nprefetch, stats.seconds,
                peak_bytes=stats.peak_loader_bytes,
                batch_seconds=getattr(stats, "batch_seconds", None),
                cache_budget_bytes=budget)
        except MemoryOverflow:
            trials[budget] = Trial(nworker, nprefetch, math.inf,
                                   overflowed=True,
                                   cache_budget_bytes=budget)
    return trials


def cache_win(trials: Dict[int, Trial], current_budget: int, *,
              min_improvement: float = 0.05) -> Optional[int]:
    """The cache-axis win test — same contract as :func:`locality_win`:
    the argmin budget must beat the CURRENT budget's own measured trial
    (Welch over per-batch samples when available, else the relative
    threshold).  Returns the winning budget, or None."""
    current_budget = max(0, int(current_budget))
    finite = {b: t for b, t in trials.items() if math.isfinite(t.seconds)}
    if not finite:
        return None
    best = min(finite, key=lambda b: finite[b].seconds)
    ref = trials.get(current_budget)
    if best == current_budget:
        return None
    if ref is None or not math.isfinite(ref.seconds):
        return best                       # nothing measured to defend
    ref_s = steady_samples(ref.batch_seconds)
    win_s = steady_samples(finite[best].batch_seconds)
    if len(ref_s) >= 2 and len(win_s) >= 2:
        return best if welch_wins(ref_s, win_s) else None
    if finite[best].seconds <= (1.0 - min_improvement) * ref.seconds:
        return best
    return None


def sweep_slow_lanes(evaluator, *, nworker: int, nprefetch: int,
                     lanes: Sequence[int], current_lanes: int,
                     num_batches: int, epoch: int = 0) -> Dict[int, Trial]:
    """Price candidate ``slow_lane_workers`` values at one (worker,
    prefetch) cell — the dual-lane analogue of :func:`sweep_locality`
    (DESIGN.md §9).

    Candidates go through the evaluator's measurement-only override, so
    the live pool's lane split is untouched; the live cost tracker keeps
    learning through the trials (trial batches are real decodes), which
    is exactly what makes a warm sweep honest — a cold tracker routes
    nothing to the slow lane and the candidate measures as pure overhead.
    """
    trials: Dict[int, Trial] = {}
    for k in dict.fromkeys([max(0, int(current_lanes)),
                            *(max(0, int(s)) for s in lanes)]):
        try:
            stats = evaluator(nworker, nprefetch, num_batches=num_batches,
                              epoch=epoch, slow_lane_workers=k)
            if stats.overflowed:
                raise MemoryOverflow("overflowed")
            trials[k] = Trial(
                nworker, nprefetch, stats.seconds,
                peak_bytes=stats.peak_loader_bytes,
                batch_seconds=getattr(stats, "batch_seconds", None),
                slow_lane_workers=k)
        except MemoryOverflow:
            trials[k] = Trial(nworker, nprefetch, math.inf,
                              overflowed=True, slow_lane_workers=k)
    return trials


def slow_lane_win(trials: Dict[int, Trial], current_lanes: int, *,
                  min_improvement: float = 0.05) -> Optional[int]:
    """The slow-lane win test — same contract as :func:`locality_win`:
    the argmin lane width must beat the CURRENT width's own measured
    trial (Welch over per-batch samples when available, else the
    relative threshold).  Returns the winning width, or None."""
    current_lanes = max(0, int(current_lanes))
    finite = {k: t for k, t in trials.items() if math.isfinite(t.seconds)}
    if not finite:
        return None
    best = min(finite, key=lambda k: finite[k].seconds)
    ref = trials.get(current_lanes)
    if best == current_lanes:
        return None
    if ref is None or not math.isfinite(ref.seconds):
        return best                       # nothing measured to defend
    ref_s = steady_samples(ref.batch_seconds)
    win_s = steady_samples(finite[best].batch_seconds)
    if len(ref_s) >= 2 and len(win_s) >= 2:
        return best if welch_wins(ref_s, win_s) else None
    if finite[best].seconds <= (1.0 - min_improvement) * ref.seconds:
        return best
    return None


# --------------------------------------------------------------------------
# counter-driven adaptive chunk sizing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AdaptiveLocalityConfig:
    # trigger: observed run length < low_watermark * active chunk
    low_watermark: float = 0.5
    # a window must contain this many storage requests before the run
    # length estimate is trusted (tiny windows are all noise)
    min_requests: int = 8
    # consecutive low windows required before proposing (one cold spike
    # must not shrink a good chunk)
    patience: int = 2
    # steps between io_counters() polls (counters are cheap but not free)
    check_every: int = 8
    # min steps between proposals (a resize latches at an epoch boundary;
    # re-proposing before the latch takes effect would thrash)
    cooldown_steps: int = 64
    # proposals snap DOWN to the largest power of two <= the observed
    # run length; below min_chunk the proposal is 0 (chunking is buying
    # nothing — fall back to the fully random order)
    min_chunk: int = 4


class AdaptiveLocalityController:
    """Closes the loop on live IO counters: shrink ``locality_chunk`` when
    the storage stops achieving it.

    Feed it either way:

    * ``step()`` — pull mode: polls ``loader.io_counters()`` every
      ``check_every`` calls (one call per train/serve step);
    * ``observe(io)`` — push mode: hand it a counters snapshot directly
      (tests, or a monitor that already polls).

    Counters are cumulative, so the controller differences consecutive
    snapshots and evaluates the *window's* achieved run length.  When the
    active chunk is C > 1 and the window's run length sits below
    ``low_watermark * C`` for ``patience`` consecutive windows, it
    proposes the largest power of two <= the observed run length (or 0
    below ``min_chunk``) — applied through ``apply_params`` so a live
    stream latches it at the next epoch boundary, or routed to
    ``on_propose`` (the fleet path: locality must change uniformly, so a
    sharded host never applies locally).
    """

    def __init__(self, loader,
                 config: Optional[AdaptiveLocalityConfig] = None, *,
                 on_propose: Optional[Callable[[int], None]] = None):
        self.loader = loader
        self.cfg = config or AdaptiveLocalityConfig()
        self.on_propose = on_propose
        self.steps = 0
        self.proposals = 0
        self.history: List[Dict[str, float]] = []
        self._last: Optional[Tuple[float, float]] = None  # (requests, misses)
        self._low_windows = 0
        self._last_proposal_step = -self.cfg.cooldown_steps

    @property
    def active_chunk(self) -> int:
        return self.loader.params.locality_chunk

    def step(self) -> Optional[int]:
        """One call per train/serve step; polls counters on schedule.
        Returns the proposed chunk when this step fired a resize."""
        self.steps += 1
        if self.steps % self.cfg.check_every:
            return None
        io = self.loader.io_counters()
        return self.observe(io) if io else None

    def observe(self, io: Dict[str, float]) -> Optional[int]:
        """Evaluate one counters snapshot; returns the proposal if fired."""
        if "coalesced_requests" not in io or "reads" not in io:
            return None
        req = float(io["coalesced_requests"])
        misses = float(io["reads"]) - float(io.get("cache_hits", 0.0))
        if self._last is None:
            self._last = (req, misses)
            return None
        d_req, d_miss = req - self._last[0], misses - self._last[1]
        self._last = (req, misses)
        chunk = self.active_chunk
        if chunk <= 1 or d_req < self.cfg.min_requests:
            self._low_windows = 0
            return None
        run_len = d_miss / d_req
        if run_len >= self.cfg.low_watermark * chunk:
            self._low_windows = 0
            return None
        self._low_windows += 1
        if self._low_windows < self.cfg.patience:
            return None
        if self.steps - self._last_proposal_step < self.cfg.cooldown_steps:
            return None
        return self._propose(run_len, chunk)

    def _propose(self, run_len: float, chunk: int) -> Optional[int]:
        if self.on_propose is None \
                and getattr(self.loader.sampler, "host_count", 1) > 1:
            # a sharded host must never change locality locally (every
            # host has to slice the SAME epoch permutation); without a
            # coordinator route there is nothing safe to do
            self._low_windows = 0
            return None
        proposal = self._snap(run_len)
        if proposal >= chunk:              # nothing smaller to propose
            self._low_windows = 0
            return None
        self._low_windows = 0
        self._last_proposal_step = self.steps
        self.proposals += 1
        self.history.append({"step": self.steps, "observed_run_len": run_len,
                             "active_chunk": chunk, "proposed": proposal})
        if self.on_propose is not None:
            # fleet path: a sharded host must not change locality locally
            self.on_propose(proposal)
        else:
            self.loader.apply_params(
                self.loader.params.replace(locality_chunk=proposal))
        return proposal

    def _snap(self, run_len: float) -> int:
        """Largest power of two <= run_len, or 0 below min_chunk (the
        storage achieves so little contiguity that random order is the
        honest setting).  The floor never drops below 2: a chunk of 0/1
        already means random order, so run lengths under 2 snap to 0
        regardless of ``min_chunk``."""
        if run_len < max(2.0, float(self.cfg.min_chunk)):
            return 0
        return 1 << (int(run_len).bit_length() - 1)
