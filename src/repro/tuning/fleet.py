"""Fleet control plane: coordinated per-host online tuning + elastic
resharding of the live data pipeline.

The single-host :class:`~repro.tuning.online.OnlineTuner` observes,
decides and acts on one machine.  A fleet serving heavy traffic needs the
same loop split across the wire: per-host optima diverge with hardware,
hosts drift, straggle and die, and a lockstep SPMD fleet's effective
transfer time is the MAX over hosts — so per-host decisions must be
coordinated to protect global goodput.

  observe — a :class:`HostAgent` on every host feeds its
            :class:`GoodputMonitor` one (data-wait, step-time) pair per
            step and streams :class:`HostReport`\\ s (goodput, stall
            ratio, per-batch seconds, stream position) to the
            coordinator.  Each ingested report is also the host's
            heartbeat.
  decide  — the :class:`FleetCoordinator` aggregates: fleet-level stall
            drift or straggler divergence declares a re-consensus;
            heartbeat timeouts declare a death; ``join`` admits a new
            host.  Warmup/cooldown/backoff bookkeeping lives here, not on
            the hosts.
  act     — re-consensus runs the existing ``tune()``/:class:`MultiHostDPT`
            machinery over every live host's evaluator and hot-swaps the
            winning uniform params into each host through
            ``apply_params``.  A death (or join) emits an elastic
            reshard: every surviving loader remaps its
            ``ShardedSampler`` shard at a common global-batch barrier,
            and the dead host's undelivered slices are redistributed as
            makeup chunks — zero samples lost, zero duplicated across
            the transition (see ``LoaderStream.apply_reshard``).

Reshard invariants (DESIGN.md §4):

* the global permutation and global-batch boundaries depend only on
  (seed, epoch, global_batch) — never on the shard topology;
* all hosts remap at the SAME absolute barrier ``B``, chosen as the max
  stream position over survivors (no host has yielded past it);
* batches before ``B`` were delivered under the old shard map (the dead
  host's own deliveries up to its last reported position included),
  batches from ``B`` on are delivered under the new map, and the dead
  host's undelivered window ``[dead_position, B)`` arrives as makeup —
  the union is every index exactly once per epoch.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dpt import DPTConfig, DPTResult, MultiHostDPT
from repro.core.monitor import MemoryOverflow
from repro.data.loader import DataLoader, LoaderParams, TransferStats
from repro.data.sampler import ShardedSampler
from repro.distributed.fault_tolerance import (HeartbeatRegistry,
                                               StragglerDetector, plan_remesh)
from repro.tuning.base import adaptive_budget
from repro.tuning.online import GoodputMonitor
from repro.tuning.transport import (AgentLink, LeaderLease, LocalTransport,
                                    SnapshotStore, StaleLeaderError,
                                    TransportError, to_wire)


# --------------------------------------------------------------------------
# consensus math (MultiHostDPT.run_uniform delegates here)
# --------------------------------------------------------------------------
def uniform_consensus(results: Sequence[DPTResult]
                      ) -> Tuple[Tuple[int, int], float]:
    """Straggler-aware minimax over per-host sweeps.

    Candidate cells are every host's trials, scored by the fleet max (the
    lockstep step time); a cell is feasible only if every host measured it
    un-overflowed.  Returns the argmin cell and its fleet time; raises
    MemoryOverflow when no cell is feasible everywhere.
    """
    per_cell: Dict[Tuple[int, int], float] = {}
    counts: Dict[Tuple[int, int], int] = {}
    for r in results:
        for t in r.trials:
            key = (t.nworker, t.nprefetch)
            per_cell[key] = max(per_cell.get(key, 0.0), t.seconds)
            if not t.overflowed and math.isfinite(t.seconds):
                counts[key] = counts.get(key, 0) + 1
    feasible = {k: v for k, v in per_cell.items()
                if counts.get(k, 0) == len(results)}
    if not feasible:
        raise MemoryOverflow("no uniform cell feasible on all hosts")
    best = min(feasible, key=feasible.get)
    return best, feasible[best]


# --------------------------------------------------------------------------
# the wire format
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HostReport:
    """One observation snapshot from a host (also its heartbeat)."""
    host: str
    steps: int                       # observations since the agent started
    consumed: int                    # absolute global-batch position trained
    position: int                    # stream yield cursor (>= consumed)
    stall_ratio: float
    steps_per_s: float
    batch_seconds: List[float]
    params: Tuple[int, int]          # current (num_workers, prefetch_factor)
    # IO-efficiency snapshot (DataLoader.io_counters: storage request
    # counters, achieved coalesced run length, staging/arena hit rates) —
    # lets retune decisions and dashboards see *locality*, not just rates.
    # None when nothing in the host's pipeline keeps counters.
    io: Optional[Dict[str, float]] = None
    # makeup chunks this host has fully CONSUMED (of all it was ever
    # dealt).  Lets a coordinator that only ever saw the host through
    # the wire reconstruct the host's undelivered-makeup backlog from
    # its own dealt log when the host dies without answering queries.
    makeup_done: int = 0


def report_to_wire(r: HostReport) -> Dict[str, Any]:
    return to_wire(dataclasses.asdict(r))


def report_from_wire(d: Dict[str, Any]) -> HostReport:
    return HostReport(
        host=str(d["host"]), steps=int(d["steps"]),
        consumed=int(d["consumed"]), position=int(d["position"]),
        stall_ratio=float(d["stall_ratio"]),
        steps_per_s=float(d["steps_per_s"]),
        batch_seconds=[float(x) for x in d.get("batch_seconds") or []],
        params=tuple(int(x) for x in d["params"]),
        io=dict(d["io"]) if d.get("io") else None,
        makeup_done=int(d.get("makeup_done", 0)))


@dataclasses.dataclass
class FleetConfig:
    heartbeat_timeout_s: float = 30.0
    # decide: aggregate drift + straggler divergence
    stall_fraction: float = 0.35     # mean stall ratio over alive hosts
    straggler_threshold: float = 1.5
    straggler_window: int = 16
    warmup_steps: int = 4            # min fleet steps before deciding
    cooldown_steps: int = 16         # fleet steps between consensus runs
    max_backoff: int = 8
    min_improvement: float = 0.05    # uniform winner must beat current cell
    # act: the consensus search (None budget derives adaptively)
    retune_budget_batches: Optional[int] = None
    max_prefetch: int = 4
    num_cpu_cores: Optional[int] = None
    num_devices: Optional[int] = None
    # online locality axis (DESIGN.md §6): candidate sampler chunk sizes a
    # re-consensus may propose.  Locality can only change UNIFORMLY on a
    # sharded fleet (every host must slice the same epoch permutation), so
    # the sweep scores candidates by the fleet max and the push pins one
    # common latch epoch on every host.  None keeps re-consensus on
    # (workers, prefetch).
    locality_chunks: Optional[Tuple[int, ...]] = None
    # online cache axis (DESIGN.md §7): candidate cross-epoch cache budgets
    # a re-consensus may propose.  The budget changes UNIFORMLY too — not
    # for correctness (each host's tier only serves its own shard) but for
    # goodput: a lockstep fleet runs at the max host time, so a budget only
    # helps when every host carries it.  Scored by the fleet max at a warm
    # epoch; None keeps re-consensus off the axis.
    cache_budgets: Optional[Tuple[int, ...]] = None
    # fault-plane consensus trigger (DESIGN.md §10): re-consensus fires
    # when any alive host's reported windowed ``fault_rate`` crosses this
    # (edge-triggered: once per excursion, plus once when the last
    # degraded host heals).  0 disables.
    fault_rate_trigger: float = 0.0
    # elastic re-mesh bookkeeping (plan_remesh)
    devices_per_host: int = 1
    model_axis: int = 1
    # elastic geometry (DESIGN.md §11): when True, a death/leave reshard
    # APPLIES plan_remesh's new_global_batch — pushed to every survivor
    # at one common epoch latch (batch boundaries are position arithmetic,
    # so the in-progress epoch finishes under the old geometry, with a
    # ragged per-host split when the old batch does not divide by the
    # survivor count).  False keeps the plan as a recorded recommendation.
    elastic_geometry: bool = True
    # consensus mode: "uniform" pushes one winning (workers, prefetch)
    # cell fleet-wide; "per_host" gives each host its own winning cell
    # AND a contiguous slice of the global batch proportional to its
    # measured delivery speed (MultiHostDPT.run_per_host), so a lockstep
    # fleet is no longer pinned to its slowest host's uniform share.
    consensus: str = "uniform"
    # survivability knobs (DESIGN.md §8)
    max_events: int = 4096           # event-log ring size (HA snapshot keeps
                                     # the monotonic seq even after eviction)
    max_barrier_rounds: int = 16     # reshard re-issue cap: a fault-injected
                                     # agent that keeps raising its effective
                                     # barrier errors out instead of spinning


class EventLog:
    """Bounded coordinator event log with a monotonic sequence number.

    PR 3 grew ``FleetCoordinator.events`` as an unbounded list — on a
    long-running fleet that is a slow memory leak and an unbounded HA
    snapshot.  This keeps the newest ``max_events`` entries, stamps each
    with a fleet-lifetime ``seq`` (stable across ring eviction AND
    coordinator failover), and still behaves like the list the tests and
    benches index/slice/iterate.
    """

    def __init__(self, max_events: int = 4096, *, start_seq: int = 0):
        self.max_events = max(1, int(max_events))
        self._items: List[Dict[str, Any]] = []
        self.next_seq = int(start_seq)

    def append(self, event: Dict[str, Any]) -> Dict[str, Any]:
        event.setdefault("seq", self.next_seq)
        self.next_seq = max(self.next_seq, int(event["seq"])) + 1
        self._items.append(event)
        if len(self._items) > self.max_events:
            del self._items[:len(self._items) - self.max_events]
        return event

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, i):
        return self._items[i]

    def __bool__(self) -> bool:
        return bool(self._items)

    def state_dict(self) -> Dict[str, Any]:
        return {"next_seq": self.next_seq, "max_events": self.max_events,
                "items": to_wire(self._items)}

    @classmethod
    def restore(cls, d: Dict[str, Any]) -> "EventLog":
        log = cls(int(d.get("max_events", 4096)))
        log._items = list(d.get("items") or [])
        log.next_seq = int(d.get("next_seq", len(log._items)))
        return log


# --------------------------------------------------------------------------
# per-host agent: observe + act, no decisions
# --------------------------------------------------------------------------
class HostAgent:
    """The fleet's presence on one host.

    Observe: ``observe(data_s, step_s)`` once per training/serving step —
    it feeds the goodput window and streams a report (the heartbeat) to
    the coordinator.  Act: ``apply_params`` / ``reshard`` are invoked BY
    the coordinator; the agent never decides anything itself.
    """

    def __init__(self, host: str, loader: DataLoader, *, evaluator=None,
                 window: int = 8, report_every: int = 1,
                 consumes_stream: bool = True,
                 link: Optional[AgentLink] = None):
        self.host = host
        self.loader = loader
        if evaluator is None:
            from repro.core.evaluators import LoaderEvaluator
            evaluator = LoaderEvaluator(loader, to_device=True)
        self.evaluator = evaluator
        self.monitor = GoodputMonitor(window=window)
        self.report_every = max(1, report_every)
        # training loops consume exactly one loader batch per observe();
        # serving frontends observe per served request-group instead, so
        # their step count says nothing about loader consumption — they
        # pass consumes_stream=False and the stream cursor is used
        self.consumes_stream = consumes_stream
        self.coordinator: Optional["FleetCoordinator"] = None
        # transport mode: reports/commands cross a message link instead of
        # direct method calls.  Exactly one of (coordinator, link) is set.
        self.link: Optional[AgentLink] = None
        if link is not None:
            self.link = link.bind(self)
        self._base = loader.sampler.absolute()
        self.steps = 0
        # which live stream the consumed-step count refers to: makeup
        # yields do not advance the regular-batch position, so the count
        # must be mapped through the stream's per-yield position log
        # rather than added to a base (see LoaderStream.position_after)
        self._consume_stream = None
        self._bind_steps = 0
        # makeup chunks ever dealt to this host (reported as makeup_done
        # minus the undelivered backlog — see HostReport.makeup_done)
        self._makeup_added = 0

    @property
    def attached(self) -> bool:
        """True when this agent reports to a control plane (in-process
        coordinator or message link)."""
        return self.coordinator is not None or self.link is not None

    # ---- observe -----------------------------------------------------------
    def observe(self, *, data_s: float, step_s: float) -> None:
        self.monitor.observe(data_s=data_s, step_s=step_s)
        self.steps += 1
        if self.consumes_stream:
            stream = self.loader._live_stream
            if stream is not None and stream is not self._consume_stream:
                # first observe against a (re)built stream: the batch just
                # consumed was that stream's first consumed yield
                self._consume_stream = stream
                self._bind_steps = self.steps - 1
        if self.steps % self.report_every == 0:
            if self.coordinator is not None:
                self.coordinator.ingest(self.report())
            elif self.link is not None:
                # never blocks: an unreachable coordinator parks the
                # report in the link's bounded queue and training
                # continues on the last latched params
                self.link.send_report(self.report_wire())

    def consumed_position(self) -> int:
        """Absolute global-batch position the CONSUMER reached (one stream
        yield per observed step for a training loop — mapped through the
        stream's position log because makeup yields do not advance the
        position; the stream cursor when the observer does not consume
        the stream batch-per-step)."""
        if not self.consumes_stream:
            return self.stream_position()
        stream = self._consume_stream
        if stream is not None and stream is self.loader._live_stream:
            return stream.position_after(self.steps - self._bind_steps)
        return self._base + self.steps

    def stream_position(self) -> int:
        """The live stream's yield cursor (>= consumed: the device
        prefetcher may hold yielded-but-unconsumed batches, which are
        guaranteed to be delivered)."""
        stream = self.loader._live_stream
        if stream is not None:
            return stream.position
        return self.loader.sampler.absolute()

    def report(self) -> HostReport:
        p = self.loader.params
        return HostReport(
            host=self.host, steps=self.steps,
            consumed=self.consumed_position(),
            position=self.stream_position(),
            stall_ratio=self.monitor.stall_ratio,
            steps_per_s=self.monitor.steps_per_s,
            batch_seconds=self.monitor.batch_seconds,
            params=(p.num_workers, p.prefetch_factor),
            io=self.loader.io_counters() or None,
            makeup_done=self._makeup_added - len(self.undelivered_makeup()))

    def report_wire(self) -> Dict[str, Any]:
        """Full report as a wire dict, carrying the host's live locality/
        cache schedules so the coordinator's shard mirror tracks plans the
        host computed locally (e.g. hot_k after a budget push).  Deltas
        drop the schedules automatically while they are unchanged."""
        d = report_to_wire(self.report())
        d["schedules"] = to_wire(self.schedule_state())
        return d

    def heartbeat(self) -> None:
        """Liveness without an observation (e.g. a serving frontend between
        batches)."""
        if self.coordinator is not None:
            self.coordinator.beat(self.host)
        elif self.link is not None:
            self.link.beat()

    def notify_drift(self, reason: str) -> None:
        """External drift signal (e.g. the serving batch-mix monitor):
        asks the coordinator for an out-of-band re-consensus."""
        if self.coordinator is not None:
            self.coordinator.request_consensus(reason=reason)
        elif self.link is not None:
            self.link.cast("drift", reason=reason)

    def notify_locality(self, chunk: int) -> None:
        """Adaptive-controller proposal (run-length collapse): locality
        may only change uniformly, so route it to the coordinator, which
        drops it when the fleet searches no locality axis."""
        if self.coordinator is not None:
            self.coordinator.request_locality(chunk, host=self.host)
        elif self.link is not None:
            self.link.cast("locality", chunk=int(chunk))

    # ---- act (coordinator-driven) ------------------------------------------
    def apply_params(self, nworker: int, nprefetch: int,
                     locality_chunk: Optional[int] = None, *,
                     locality_epoch: Optional[int] = None,
                     cache_budget_bytes: Optional[int] = None
                     ) -> LoaderParams:
        """Push tuned params into the live loader.  ``locality_chunk`` and
        ``cache_budget_bytes`` are only ever set by a fleet-uniform push,
        which also pins the common ``locality_epoch`` every host latches
        the new chunk (and cache plan) at.  A budget push resizes the
        host's live tier in place — warm entries survive the swap."""
        params = self.loader.params.replace(
            num_workers=nworker, prefetch_factor=nprefetch)
        if locality_chunk is not None:
            params = params.replace(locality_chunk=locality_chunk)
        if cache_budget_bytes is not None:
            params = params.replace(cache_budget_bytes=cache_budget_bytes)
        return self.loader.apply_params(params,
                                        locality_epoch=locality_epoch)

    def reshard(self, num_shards: int, shard: int, *,
                at_batch: Optional[int] = None,
                makeup: Optional[Sequence[np.ndarray]] = None,
                sizes: Optional[Sequence[int]] = None,
                op_id: Optional[str] = None) -> int:
        # op_id is the wire-level idempotency token; the in-process path
        # needs no dedup (calls are exactly-once on a stack)
        del op_id
        if makeup:
            self._makeup_added += len(makeup)
        return self.loader.reshard(num_shards, shard, at_batch=at_batch,
                                   makeup=makeup, sizes=sizes)

    def set_geometry(self, global_batch: int, *,
                     epoch: Optional[int] = None,
                     op_id: Optional[str] = None) -> int:
        """Adopt a new global batch from ``epoch`` on (elastic geometry
        push — see DataLoader.set_geometry)."""
        del op_id
        return self.loader.set_geometry(int(global_batch), epoch=epoch)

    def add_makeup(self, makeup: Sequence[np.ndarray], *,
                   op_id: Optional[str] = None) -> None:
        del op_id
        self._makeup_added += len(makeup)
        self.loader.add_makeup(makeup)

    def undelivered_makeup(self) -> List[np.ndarray]:
        """Makeup this host accepted but never CONSUMED — including
        batches its device prefetcher held at death (the stream's
        yield-side accounting alone would count those as delivered)."""
        stream = self._consume_stream
        if self.consumes_stream and stream is not None \
                and stream is self.loader._live_stream:
            return stream.undelivered_makeup(
                consumed_yields=self.steps - self._bind_steps)
        return self.loader.undelivered_makeup()

    def align_to(self, position: int) -> None:
        """Point a FRESH loader (no live stream yet) at an absolute
        global-batch position — how a joining host meets the fleet at the
        barrier."""
        sampler = self.loader.sampler
        sampler.state = sampler.state_at(position)
        self._base = position
        self.steps = 0
        self._consume_stream = None
        self._bind_steps = 0

    # ---- fleet-member surface ----------------------------------------------
    # The coordinator only ever speaks this narrow API — implemented
    # natively here (direct mode) and over the wire by RemoteAgent, so
    # the decide logic is transport-agnostic.
    def param_cell(self) -> Tuple[int, int]:
        p = self.loader.params
        return (p.num_workers, p.prefetch_factor)

    def knob_state(self) -> Dict[str, Any]:
        p = self.loader.params
        return {"locality_chunk": p.locality_chunk,
                "cache_budget_bytes": p.cache_budget_bytes}

    def locality_latch_epoch(self) -> int:
        return self.loader.locality_latch_epoch()

    def shard_index(self) -> int:
        return self.loader.sampler.host_index

    def global_batch(self) -> int:
        return self.loader.sampler.global_batch

    def shard_sizes(self) -> Optional[List[int]]:
        s = self.loader.sampler.shard_sizes
        return None if s is None else list(s)

    def batches_per_epoch(self, epoch: Optional[int] = None) -> int:
        return self.loader.sampler.batches_per_epoch(epoch)

    def local_indices(self, epoch: int, batch: int) -> np.ndarray:
        return self.loader.sampler.local_indices(epoch, batch)

    def local_indices_at(self, position: int) -> np.ndarray:
        """This host's slice at an absolute global-batch position —
        schedule-aware (epochs can have different lengths under an
        elastic geometry schedule)."""
        s = self.loader.sampler
        st = s.state_at(int(position))
        return s.local_indices(st.epoch, st.batch_offset)

    def schedule_state(self) -> Dict[str, Any]:
        """The uniform-permutation contract: the full (epoch -> chunk),
        (epoch -> hot_k) and (epoch -> global_batch) schedules plus the
        params they came from."""
        s = self.loader.sampler
        return {"locality": s.locality_state(), "cache": s.cache_state(),
                "geometry": s.geometry_state(), **self.knob_state()}

    def sync_schedules(self, sched: Dict[str, Any]) -> None:
        """Adopt a peer's full epoch schedules (join catch-up, partition
        re-sync) so this host slices the same permutation as the fleet."""
        loader = self.loader
        if sched.get("locality") is not None:
            loader.sampler.load_locality(sched["locality"])
        if sched.get("cache") is not None:
            loader.sampler.load_cache_plan(sched["cache"])
        if sched.get("geometry") is not None:
            loader.sampler.load_geometry(sched["geometry"])
        chunk = sched.get("locality_chunk")
        budget = sched.get("cache_budget_bytes")
        loader.params = loader.params.replace(
            locality_chunk=loader.params.locality_chunk if chunk is None
            else int(chunk),
            cache_budget_bytes=loader.params.cache_budget_bytes
            if budget is None else int(budget))
        loader._sync_cache_plan()

    def begin_trials(self) -> None:
        """Bracket a coordinator-driven measurement burst: trial cells
        mutate loader params via with_params; a live stream must never
        rebuild on trial params."""
        self._trial_params = self.loader.params

    def end_trials(self) -> None:
        saved = getattr(self, "_trial_params", None)
        if saved is not None:
            self.loader.with_params(saved)
            self._trial_params = None

    # ---- transport glue ----------------------------------------------------
    def member_spec(self) -> Dict[str, Any]:
        """Everything the coordinator needs to mirror this host's shard
        map without object access — crossed once at register/join."""
        s = self.loader.sampler
        p = self.loader.params
        return {"host": self.host,
                "position": self.stream_position(),
                "sampler": {"num_items": s.num_items,
                            "global_batch": s.global_batch,
                            "shuffle": s.shuffle, "seed": s.seed,
                            "drop_last": s.drop_last,
                            "host_index": s.host_index,
                            "host_count": s.host_count,
                            "layout": s.layout,
                            "locality": s.locality_state(),
                            "cache": s.cache_state(),
                            "geometry": s.geometry_state(),
                            "sizes": None if s.shard_sizes is None
                            else list(s.shard_sizes)},
                "params": {"num_workers": p.num_workers,
                           "prefetch_factor": p.prefetch_factor,
                           "locality_chunk": p.locality_chunk,
                           "cache_budget_bytes": p.cache_budget_bytes}}

    def ha_state(self) -> Dict[str, Any]:
        """Snapshot form of this member for the coordinator HA checkpoint
        (direct-mode agents serialize their spec; the dealt-makeup log is
        empty because direct mode never loses the object)."""
        return {"spec": self.member_spec(), "dealt": [],
                "report": report_to_wire(self.report())}

    def handle_command(self, op: str, args: Dict[str, Any]) -> Any:
        """Wire command dispatch (invoked by AgentLink AFTER its fence and
        dedup checks).  Every coordinator->agent verb crosses here."""
        if op == "apply_params":
            p = self.apply_params(
                int(args["nworker"]), int(args["nprefetch"]),
                None if args.get("locality_chunk") is None
                else int(args["locality_chunk"]),
                locality_epoch=None if args.get("locality_epoch") is None
                else int(args["locality_epoch"]),
                cache_budget_bytes=None
                if args.get("cache_budget_bytes") is None
                else int(args["cache_budget_bytes"]))
            return {"num_workers": p.num_workers,
                    "prefetch_factor": p.prefetch_factor}
        if op == "reshard":
            makeup = None
            if args.get("makeup") is not None:
                makeup = [np.asarray(c, dtype=np.int64)
                          for c in args["makeup"]]
            return self.reshard(
                int(args["num_shards"]), int(args["shard"]),
                at_batch=None if args.get("at_batch") is None
                else int(args["at_batch"]),
                makeup=makeup,
                sizes=None if args.get("sizes") is None
                else [int(s) for s in args["sizes"]])
        if op == "set_geometry":
            return self.set_geometry(
                int(args["global_batch"]),
                epoch=None if args.get("epoch") is None
                else int(args["epoch"]))
        if op == "add_makeup":
            self.add_makeup([np.asarray(c, dtype=np.int64)
                             for c in args["chunks"]])
            return len(args["chunks"])
        if op == "align_to":
            self.align_to(int(args["position"]))
            return int(args["position"])
        if op == "sync_schedules":
            self.sync_schedules(args["sched"])
            return True
        if op == "query":
            what = args.get("what")
            if what == "stream_position":
                return self.stream_position()
            if what == "consumed_position":
                return self.consumed_position()
            if what == "locality_latch_epoch":
                return self.locality_latch_epoch()
            if what == "schedule_state":
                return self.schedule_state()
            if what == "params":
                return {"cell": list(self.param_cell()),
                        **self.knob_state()}
            raise ValueError(f"unknown query {what!r}")
        if op == "measure":
            # trial measurement on behalf of a remote consensus: run the
            # local evaluator and ALWAYS restore live params (the remote
            # coordinator cannot reach in to clean up)
            saved = self.loader.params
            kw: Dict[str, Any] = {
                "num_batches": int(args.get("num_batches", 16)),
                "epoch": int(args.get("epoch", 0))}
            # forward the extra axes only when set: plain 2-axis
            # evaluators (and the sweep helpers) do not take them
            if args.get("locality_chunk") is not None:
                kw["locality_chunk"] = int(args["locality_chunk"])
            if args.get("cache_budget_bytes") is not None:
                kw["cache_budget_bytes"] = int(args["cache_budget_bytes"])
            if args.get("global_batch") is not None:
                kw["global_batch"] = int(args["global_batch"])
            try:
                stats = self.evaluator(
                    int(args["nworker"]), int(args["nprefetch"]), **kw)
                return to_wire(dataclasses.asdict(stats))
            except MemoryOverflow as e:
                return {"overflow": True, "error": str(e)}
            finally:
                self.loader.with_params(saved)
        if op == "ping":
            return True
        raise ValueError(f"unknown command {op!r}")


# --------------------------------------------------------------------------
# the coordinator-side proxy: a fleet member that lives across the wire
# --------------------------------------------------------------------------
class _RemoteEvaluator:
    """Evaluator facade over a RemoteAgent: a consensus trial becomes a
    ``measure`` command; the host runs its real evaluator and ships the
    TransferStats (or an overflow verdict) back as data."""

    def __init__(self, proxy: "RemoteAgent"):
        self.proxy = proxy
        self.calls = 0

    def __call__(self, nworker: int, nprefetch: int, *,
                 num_batches: int = 16, epoch: int = 0,
                 locality_chunk: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 global_batch: Optional[int] = None) -> TransferStats:
        self.calls += 1
        r = self.proxy._send("measure", {
            "nworker": nworker, "nprefetch": nprefetch,
            "num_batches": num_batches, "epoch": epoch,
            "locality_chunk": locality_chunk,
            "cache_budget_bytes": cache_budget_bytes,
            "global_batch": global_batch})
        if r.get("overflow"):
            raise MemoryOverflow(r.get("error", "remote overflow"))
        return TransferStats(
            seconds=float(r["seconds"]), batches=int(r["batches"]),
            bytes=int(r["bytes"]), overflowed=bool(r.get("overflowed")),
            peak_loader_bytes=int(r.get("peak_loader_bytes", 0)),
            batch_seconds=r.get("batch_seconds"))


class RemoteAgent:
    """The coordinator's view of a host it can only reach by message.

    Implements the same fleet-member surface as :class:`HostAgent`, but
    every act crosses the transport as a fenced, idempotent command —
    and the *observe* side keeps a local mirror (a ShardedSampler built
    from the registration spec, updated on acked reshards/pushes and on
    report schedules) so the coordinator can compute a DEAD host's
    undelivered slices without asking it anything.  The mirror plus the
    dealt-makeup log is exactly the state the direct-mode coordinator
    used to read out of the departed agent object.
    """

    def __init__(self, server: "CoordinatorServer", spec: Dict[str, Any], *,
                 dealt: Optional[List] = None,
                 report: Optional[Dict[str, Any]] = None):
        self.host = str(spec["host"])
        self._server = server
        self._base = int(spec.get("position", 0))
        sp = spec["sampler"]
        self._sampler = ShardedSampler(
            int(sp["num_items"]), int(sp["global_batch"]),
            shuffle=bool(sp["shuffle"]), seed=int(sp["seed"]),
            drop_last=bool(sp["drop_last"]),
            host_index=int(sp["host_index"]),
            host_count=int(sp["host_count"]),
            layout=sp.get("layout", "host_major"),
            shard_sizes=None if sp.get("sizes") is None
            else [int(s) for s in sp["sizes"]])
        if sp.get("locality"):
            self._sampler.load_locality(sp["locality"])
        if sp.get("cache"):
            self._sampler.load_cache_plan(sp["cache"])
        if sp.get("geometry"):
            self._sampler.load_geometry(sp["geometry"])
        self._params = dict(spec["params"])
        self._dealt: List[np.ndarray] = [
            np.asarray(c, dtype=np.int64) for c in (dealt or [])]
        self.last_report: Optional[HostReport] = \
            None if report is None else report_from_wire(report)
        self.coordinator: Optional["FleetCoordinator"] = None
        self.evaluator = _RemoteEvaluator(self)

    def _send(self, op: str, args: Dict[str, Any],
              op_id: Optional[str] = None) -> Any:
        return self._server.send(self.host, op, args, op_id=op_id)

    # ---- observe -----------------------------------------------------------
    def observe_report(self, report: HostReport,
                       schedules: Optional[Dict[str, Any]] = None) -> None:
        """Fold an ACCEPTED report into the mirror (the server calls this
        after the coordinator's stale-steps guard passed)."""
        self.last_report = report
        self._params["num_workers"], self._params["prefetch_factor"] = \
            (int(report.params[0]), int(report.params[1]))
        if schedules:
            if schedules.get("locality") is not None:
                self._sampler.load_locality(schedules["locality"])
            if schedules.get("cache") is not None:
                self._sampler.load_cache_plan(schedules["cache"])
            if schedules.get("geometry") is not None:
                self._sampler.load_geometry(schedules["geometry"])
            if schedules.get("locality_chunk") is not None:
                self._params["locality_chunk"] = \
                    int(schedules["locality_chunk"])
            if schedules.get("cache_budget_bytes") is not None:
                self._params["cache_budget_bytes"] = \
                    int(schedules["cache_budget_bytes"])

    # ---- member surface: reads ---------------------------------------------
    def stream_position(self) -> int:
        return int(self._send("query", {"what": "stream_position"}))

    def consumed_position(self) -> int:
        """From the last report — NEVER an RPC: this is only ever read for
        departed hosts, which by definition cannot answer."""
        if self.last_report is not None:
            return int(self.last_report.consumed)
        return self._base

    def undelivered_makeup(self) -> List[np.ndarray]:
        """The dealt-log tail the host never consumed (makeup parked on a
        corpse) — reconstructed coordinator-side from makeup_done."""
        done = 0 if self.last_report is None \
            else max(0, int(self.last_report.makeup_done))
        return [np.array(c, dtype=np.int64) for c in self._dealt[done:]]

    def param_cell(self) -> Tuple[int, int]:
        return (int(self._params["num_workers"]),
                int(self._params["prefetch_factor"]))

    def knob_state(self) -> Dict[str, Any]:
        return {"locality_chunk": int(self._params.get("locality_chunk", 0)),
                "cache_budget_bytes":
                    int(self._params.get("cache_budget_bytes", 0))}

    def locality_latch_epoch(self) -> int:
        return int(self._send("query", {"what": "locality_latch_epoch"}))

    def shard_index(self) -> int:
        return self._sampler.host_index

    def global_batch(self) -> int:
        return self._sampler.global_batch

    def shard_sizes(self) -> Optional[List[int]]:
        s = self._sampler.shard_sizes
        return None if s is None else list(s)

    def batches_per_epoch(self, epoch: Optional[int] = None) -> int:
        return self._sampler.batches_per_epoch(epoch)

    def local_indices(self, epoch: int, batch: int) -> np.ndarray:
        return self._sampler.local_indices(epoch, batch)

    def local_indices_at(self, position: int) -> np.ndarray:
        st = self._sampler.state_at(int(position))
        return self._sampler.local_indices(st.epoch, st.batch_offset)

    def schedule_state(self) -> Dict[str, Any]:
        return {"locality": self._sampler.locality_state(),
                "cache": self._sampler.cache_state(),
                "geometry": self._sampler.geometry_state(),
                **self.knob_state()}

    # ---- member surface: fenced acts ---------------------------------------
    def apply_params(self, nworker: int, nprefetch: int,
                     locality_chunk: Optional[int] = None, *,
                     locality_epoch: Optional[int] = None,
                     cache_budget_bytes: Optional[int] = None) -> None:
        self._send("apply_params", {
            "nworker": nworker, "nprefetch": nprefetch,
            "locality_chunk": locality_chunk,
            "locality_epoch": locality_epoch,
            "cache_budget_bytes": cache_budget_bytes})
        self._params["num_workers"] = int(nworker)
        self._params["prefetch_factor"] = int(nprefetch)
        if locality_chunk is not None:
            self._params["locality_chunk"] = int(locality_chunk)
            self._sampler.set_locality(int(locality_chunk),
                                       epoch=locality_epoch)
        if cache_budget_bytes is not None:
            self._params["cache_budget_bytes"] = int(cache_budget_bytes)

    def reshard(self, num_shards: int, shard: int, *,
                at_batch: Optional[int] = None,
                makeup: Optional[Sequence[np.ndarray]] = None,
                sizes: Optional[Sequence[int]] = None,
                op_id: Optional[str] = None) -> int:
        args: Dict[str, Any] = {"num_shards": num_shards, "shard": shard,
                                "at_batch": at_batch}
        if makeup:
            args["makeup"] = [np.asarray(c).tolist() for c in makeup]
        if sizes is not None:
            args["sizes"] = [int(s) for s in sizes]
        effective = int(self._send("reshard", args, op_id=op_id))
        # the ack means the host applied it: mirror follows
        self._sampler.reshard(num_shards, shard, sizes=sizes)
        if makeup:
            self._dealt.extend(np.asarray(c, dtype=np.int64) for c in makeup)
        return effective

    def set_geometry(self, global_batch: int, *,
                     epoch: Optional[int] = None,
                     op_id: Optional[str] = None) -> int:
        eff = int(self._send("set_geometry",
                             {"global_batch": int(global_batch),
                              "epoch": epoch}, op_id=op_id))
        # mirror at the host's EFFECTIVE epoch (its natural latch may
        # have clamped a stale pin upward)
        self._sampler.set_geometry(int(global_batch), epoch=eff)
        return eff

    def add_makeup(self, makeup: Sequence[np.ndarray], *,
                   op_id: Optional[str] = None) -> None:
        self._send("add_makeup",
                   {"chunks": [np.asarray(c).tolist() for c in makeup]},
                   op_id=op_id)
        self._dealt.extend(np.asarray(c, dtype=np.int64) for c in makeup)

    def align_to(self, position: int) -> None:
        self._send("align_to", {"position": int(position)})
        self._base = int(position)

    def sync_schedules(self, sched: Dict[str, Any]) -> None:
        self._send("sync_schedules", {"sched": to_wire(sched)})
        if sched.get("locality") is not None:
            self._sampler.load_locality(sched["locality"])
        if sched.get("cache") is not None:
            self._sampler.load_cache_plan(sched["cache"])
        if sched.get("geometry") is not None:
            self._sampler.load_geometry(sched["geometry"])
        if sched.get("locality_chunk") is not None:
            self._params["locality_chunk"] = int(sched["locality_chunk"])
        if sched.get("cache_budget_bytes") is not None:
            self._params["cache_budget_bytes"] = \
                int(sched["cache_budget_bytes"])

    def begin_trials(self) -> None:
        """No-op: the host-side ``measure`` handler restores its own live
        params around every trial."""

    def end_trials(self) -> None:
        pass

    # ---- HA snapshot -------------------------------------------------------
    def ha_state(self) -> Dict[str, Any]:
        s = self._sampler
        return {"spec": {"host": self.host, "position": self._base,
                         "sampler": {"num_items": s.num_items,
                                     "global_batch": s.global_batch,
                                     "shuffle": s.shuffle, "seed": s.seed,
                                     "drop_last": s.drop_last,
                                     "host_index": s.host_index,
                                     "host_count": s.host_count,
                                     "layout": s.layout,
                                     "locality": s.locality_state(),
                                     "cache": s.cache_state(),
                                     "geometry": s.geometry_state(),
                                     "sizes": None if s.shard_sizes is None
                                     else list(s.shard_sizes)},
                         "params": dict(self._params)},
                "dealt": [c.tolist() for c in self._dealt],
                "report": None if self.last_report is None
                else report_to_wire(self.last_report)}

    @classmethod
    def restore(cls, server: "CoordinatorServer",
                state: Dict[str, Any]) -> "RemoteAgent":
        return cls(server, state["spec"], dealt=state.get("dealt"),
                   report=state.get("report"))


# --------------------------------------------------------------------------
# the coordinator: decide
# --------------------------------------------------------------------------
class FleetCoordinator:
    """Aggregates host reports and drives fleet-wide tuning + resharding.

    Drive it with ``ingest``/``beat`` (or let registered agents do that
    through ``observe``) and call ``poll()`` from the control loop —
    every action taken is appended to ``events`` and returned.
    """

    def __init__(self, *, config: Optional[FleetConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        # default None, constructed per-instance: a module-level default
        # FleetConfig() would be one shared mutable object across every
        # coordinator ever constructed
        config = FleetConfig() if config is None else config
        self.cfg = config
        self.clock = clock
        self.registry = HeartbeatRegistry(
            timeout_s=config.heartbeat_timeout_s, clock=clock)
        self.straggler = StragglerDetector(
            window=config.straggler_window,
            threshold=config.straggler_threshold)
        self.agents: Dict[str, Any] = {}   # HostAgent | RemoteAgent
        self.reports: Dict[str, HostReport] = {}
        self.events = EventLog(config.max_events)
        self.consensus_runs = 0
        self.reshards = 0
        self._last_consensus_step = -config.cooldown_steps
        self._backoff = 1
        self._forced_reason: Optional[str] = None
        # stale/duplicate-report guard: highest steps counter accepted per
        # host — a replayed or reordered report must not rewind bookkeeping
        self._last_steps: Dict[str, int] = {}
        self.stale_reports = 0
        # fault-plane edge state (DESIGN.md §10): True while the fleet is
        # inside a fault excursion (rate over trigger or a host degraded)
        self._fleet_faulted = False
        # HA plumbing (set by CoordinatorServer / restore)
        self._server: Optional["CoordinatorServer"] = None
        self._store: Optional[SnapshotStore] = None
        self._member_state: Optional[Dict[str, Any]] = None
        self._pending_reshard: Optional[Dict[str, Any]] = None
        # last applied uniform push (re-sync source for reconnecting hosts)
        self._pushed: Optional[Dict[str, Any]] = None

    # ---- membership --------------------------------------------------------
    def register(self, agent) -> Any:
        agent.coordinator = self
        self.agents[agent.host] = agent
        self.registry.beat(agent.host)
        # a (re)joining host restarts its steps counter: reset the stale
        # guard or every report from its new life would be dropped
        self._last_steps.pop(agent.host, None)
        return agent

    def _negotiate_barrier(self, agents: Sequence[Any], num_shards: int,
                           floor: int, *, rid: Optional[int] = None,
                           sizes: Optional[Sequence[int]] = None) -> int:
        """Issue the reshard to every agent at a common barrier, re-issuing
        at the max EFFECTIVE barrier until it is common.

        A live stream whose prefetcher raced past the proposed barrier
        clamps its boundary up and reports it; since a pending request
        pins the stream at its boundary, each re-issue round can only
        raise the barrier and the loop converges (normally in one pass).
        ``max_barrier_rounds`` caps the loop: a faulty agent that keeps
        raising its effective barrier produces a clear diagnostic instead
        of an infinite spin.

        ``sizes`` (optional) is a per-shard split of the global batch —
        host-major contiguous slices — forwarded to every agent so a
        ragged or deliberately non-uniform partition lands fleet-wide at
        the same barrier.
        """
        barrier = max([a.stream_position() for a in agents] + [floor])
        history: List[int] = []
        for _ in range(max(1, self.cfg.max_barrier_rounds)):
            effective = max(
                a.reshard(num_shards, i, at_batch=barrier, sizes=sizes,
                          op_id=None if rid is None
                          else f"reshard-{rid}-map-{a.host}-{barrier}")
                for i, a in enumerate(agents))
            history.append(effective)
            if effective <= barrier:
                return barrier
            barrier = effective
        positions = {a.host: a.stream_position() for a in agents}
        raise RuntimeError(
            f"reshard barrier failed to settle after "
            f"{self.cfg.max_barrier_rounds} rounds: effective barriers "
            f"{history}, stream positions {positions} — some agent keeps "
            f"racing past every proposed barrier")

    def join(self, agent) -> int:
        """Admit a new host mid-run: every existing host reshards to
        H+1 shards at a common barrier, the newcomer is aligned to that
        barrier and takes the last shard.  Returns the barrier."""
        incumbents = [self.agents[h] for h in sorted(self.agents)]
        new_count = len(incumbents) + 1
        rid = self.reshards
        barrier = self._negotiate_barrier(incumbents, new_count, 0, rid=rid)
        if incumbents:
            # locality is runtime-mutable now: the joiner's construction-
            # time chunk can be stale, and a host slicing a different
            # epoch permutation than its peers silently loses/duplicates
            # samples.  Copy an incumbent's full (epoch -> chunk) AND
            # (epoch -> hot_k) AND (epoch -> global_batch) schedules —
            # including any pending latch — BEFORE aligning: align_to
            # converts the barrier to (epoch, offset) through the
            # geometry schedule, so the joiner must hold the fleet's
            # schedule first or it lands on the wrong epoch boundary.
            agent.sync_schedules(incumbents[0].schedule_state())
        agent.align_to(barrier)
        agent.reshard(new_count, new_count - 1,
                      op_id=f"reshard-{rid}-align-{agent.host}")
        self.register(agent)
        self.reshards += 1
        self.events.append({"kind": "join", "host": agent.host,
                            "barrier": barrier, "hosts": new_count})
        # the local batch shrank on every incumbent: re-tune for the new
        # topology at the next poll
        if self._forced_reason is None:
            self._forced_reason = "post-reshard"
        self._checkpoint()
        return barrier

    def leave(self, host: str) -> None:
        """Graceful departure: same reshard as a death, but the host's
        stream position needs no makeup beyond its own report."""
        self._reshard_around([host], reason="leave")

    # ---- observe ingestion -------------------------------------------------
    def beat(self, host: str) -> None:
        self.registry.beat(host)

    def ingest(self, report: HostReport) -> bool:
        """Fold one host report in.  Returns True when accepted.

        Stale/duplicate guard: a replayed, reordered or duplicated report
        whose ``steps`` counter is not beyond the last accepted one for
        that host still counts as a heartbeat (the bytes arrived NOW, so
        something over there is alive) but must not rewind consumed/
        position bookkeeping or re-feed the straggler windows.
        """
        self.registry.beat(report.host)
        last = self._last_steps.get(report.host)
        if last is not None and report.steps <= last:
            self.stale_reports += 1
            return False
        self._last_steps[report.host] = report.steps
        if report.batch_seconds:
            self.straggler.record(
                report.host,
                sum(report.batch_seconds) / len(report.batch_seconds))
        self.reports[report.host] = report
        return True

    def request_consensus(self, *, reason: str) -> None:
        """Out-of-band drift signal (serving batch-mix, operator): run a
        re-consensus at the next ``poll`` regardless of cooldown."""
        self._forced_reason = reason

    def request_locality(self, chunk: int, *, host: str = "?") -> None:
        """A host's adaptive locality controller observed a run-length
        collapse.  Locality can only change uniformly, so this requests a
        locality re-consensus — and is DROPPED when the fleet searches no
        locality axis (``FleetConfig.locality_chunks`` unset): a forced
        search that cannot touch the knob would just burn goodput on
        every repeated proposal."""
        if not self.cfg.locality_chunks:
            return
        self.request_consensus(
            reason=f"locality-run-len-collapse:{host}->{int(chunk)}")

    # ---- decide ------------------------------------------------------------
    @property
    def fleet_step(self) -> int:
        return max((r.steps for r in self.reports.values()), default=0)

    def fleet_stall_ratio(self) -> float:
        alive = set(self.registry.alive_hosts())
        ratios = [r.stall_ratio for h, r in self.reports.items()
                  if h in alive]
        return sum(ratios) / len(ratios) if ratios else 0.0

    def drifted(self) -> bool:
        return self.fleet_stall_ratio() > self.cfg.stall_fraction

    def fleet_fault_rate(self) -> float:
        """Worst windowed fault rate over alive hosts (DESIGN.md §10).
        A lockstep fleet runs at the max host time, so one browning-out
        host is a fleet problem — max, not mean."""
        alive = set(self.registry.alive_hosts())
        rates = [float((r.io or {}).get("fault_rate", 0.0))
                 for h, r in self.reports.items() if h in alive]
        return max(rates) if rates else 0.0

    def fleet_degraded(self) -> bool:
        alive = set(self.registry.alive_hosts())
        return any(float((r.io or {}).get("degraded", 0.0)) >= 1.0
                   for h, r in self.reports.items() if h in alive)

    def _fault_reason(self) -> Optional[str]:
        """Edge-triggered fault consensus: fire once entering an
        excursion (fault-drift) and once leaving it (fault-heal), never
        continuously — a browning-out backend must not make the control
        plane retune in a loop."""
        if self.cfg.fault_rate_trigger <= 0.0:
            return None
        faulted = (self.fleet_fault_rate() > self.cfg.fault_rate_trigger
                   or self.fleet_degraded())
        if faulted and not self._fleet_faulted:
            self._fleet_faulted = True
            return "fault-drift"
        if not faulted and self._fleet_faulted:
            self._fleet_faulted = False
            return "fault-heal"
        return None

    def poll(self) -> List[Dict[str, Any]]:
        """One decide step: finish any interrupted reshard, handle deaths,
        then drift/straggler consensus.  Returns the actions taken (also
        appended to ``events``)."""
        actions: List[Dict[str, Any]] = []
        # a reshard interrupted by a flaky wire (partitioned survivor mid-
        # deal) left its write-ahead intent checkpointed: resume it before
        # deciding anything else — the frozen shares re-deal under their
        # original op-ids, so a survivor that DID get its share applies it
        # exactly once.  Still unreachable -> stays pending for next poll.
        if self._pending_reshard is not None and self._server is not None:
            ev = self._absorb_transport(self._resume_reshard)
            if ev is not None:
                actions.append(ev)
        dead = [h for h in self.registry.dead_hosts() if h in self.agents]
        if dead:
            # one reshard around ALL currently-dead hosts: handling them
            # one at a time would hand a dead "survivor" a shard (and a
            # makeup share) it can never deliver
            ev = self._absorb_transport(
                lambda: self._reshard_around(dead, reason="dead"))
            if ev is not None:
                actions.append(ev)
        reason = self._consensus_reason()
        if reason is not None:
            act = self._absorb_transport(lambda: self._reconsensus(reason))
            if act is not None:
                actions.append(act)
        return actions

    def _absorb_transport(self, fn: Callable[[], Optional[Dict[str, Any]]]
                          ) -> Optional[Dict[str, Any]]:
        """Run one decide action, absorbing TRANSIENT wire failures: a
        host that cannot be reached right now fails the action, not the
        control plane (an interrupted reshard stays write-ahead-logged
        and resumes next poll).  Deposition is never absorbed — a stale
        fence means a newer leader owns the fleet and this one must stop.
        Direct in-process mode (no server) has no wire to absorb."""
        if self._server is None:
            return fn()
        try:
            return fn()
        except StaleLeaderError:
            raise
        except TransportError:
            return None

    def _consensus_reason(self) -> Optional[str]:
        if self._forced_reason is not None:
            reason, self._forced_reason = self._forced_reason, None
            return reason
        if self.fleet_step < self.cfg.warmup_steps:
            return None
        cooldown = self.cfg.cooldown_steps * self._backoff
        if self.fleet_step - self._last_consensus_step < cooldown:
            return None
        stragglers = self.straggler.stragglers()
        if stragglers:
            return f"straggler-divergence:{','.join(stragglers)}"
        if self.drifted():
            return "goodput-drift"
        return self._fault_reason()

    # ---- act: uniform re-consensus -----------------------------------------
    def _search_config(self) -> DPTConfig:
        cfg = DPTConfig(num_cpu_cores=self.cfg.num_cpu_cores,
                        num_devices=self.cfg.num_devices,
                        max_prefetch=self.cfg.max_prefetch)
        return dataclasses.replace(cfg, num_batches=adaptive_budget(
            cfg, self.cfg.retune_budget_batches))

    def _reconsensus(self, reason: str) -> Optional[Dict[str, Any]]:
        """Uniform re-consensus over every live host's evaluator, pushed
        to the whole fleet through apply_params.  With
        ``cfg.consensus == "per_host"`` the fleet instead tunes each host
        independently and re-balances the batch partition to match the
        measured per-host rates (see :meth:`_per_host_consensus`)."""
        if self.cfg.consensus == "per_host":
            return self._per_host_consensus(reason)
        hosts = sorted(h for h in self.agents
                       if h in set(self.registry.alive_hosts()))
        if not hosts:
            return None
        agents = [self.agents[h] for h in hosts]
        tuner = MultiHostDPT([a.evaluator for a in agents],
                             self._search_config())
        self._last_consensus_step = self.fleet_step
        for a in agents:
            a.begin_trials()
        try:
            fleet = tuner.run_uniform()
        except MemoryOverflow:
            self._backoff = min(self.cfg.max_backoff, self._backoff * 2)
            return None
        finally:
            # trial cells mutate loader params via with_params; a live
            # stream must never rebuild on trial params
            for a in agents:
                a.end_trials()
        self.consensus_runs += 1
        won = self._is_fleet_win(fleet, agents)
        # the online locality axis: sweep chunk candidates at the cell the
        # fleet will actually run (the winner if it won, else the current
        # majority cell), scored by the fleet max
        cell = fleet.uniform_params if won \
            else self._majority_cell(agents)
        chunk_win = self._locality_consensus(agents, cell)
        budget_win = self._cache_consensus(agents, cell)
        applied = won or chunk_win is not None or budget_win is not None
        self._backoff = 1 if applied else min(self.cfg.max_backoff,
                                              self._backoff * 2)
        event = {"kind": "consensus", "reason": reason,
                 "params": fleet.uniform_params,
                 "fleet_time": fleet.fleet_time, "hosts": hosts,
                 # "applied" = anything changed; "cell_applied" = the
                 # uniform (workers, prefetch) winner itself rolled out
                 # (False for a locality-only apply: hosts keep their
                 # current cells and only the chunk changes)
                 "cell_applied": won,
                 "locality_chunk": chunk_win,
                 "cache_budget_bytes": budget_win,
                 "applied": applied}
        self.events.append(event)
        if applied:
            # one common latch epoch: every host adopts the new chunk AND
            # the new cache plan for the SAME epoch even when producers
            # straddle a boundary (the interleaved order depends on both)
            latch = max(a.locality_latch_epoch() for a in agents) \
                if (chunk_win is not None or budget_win is not None) \
                else None
            for a in agents:
                nw, npf = fleet.uniform_params if won else a.param_cell()
                a.apply_params(nw, npf, locality_chunk=chunk_win,
                               locality_epoch=latch,
                               cache_budget_bytes=budget_win)
            # remember what went out: a host that was partitioned through
            # this push re-syncs from here on reconnect
            self._pushed = {
                "cell": list(fleet.uniform_params) if won else None,
                "schedule": to_wire(agents[0].schedule_state())}
        self._checkpoint()
        return event

    @staticmethod
    def _apportion(total: int, weights: Sequence[float]) -> List[int]:
        """Split ``total`` into ``len(weights)`` non-negative integer parts
        proportional to ``weights`` (largest-remainder), with every part
        clamped to >= 1 when ``total >= len(weights)`` — a host with a
        terrible measurement still needs a non-empty slice or it starves
        out of the lockstep.  Zero/degenerate weights fall back to an even
        split."""
        parts = len(weights)
        w = [max(0.0, float(x)) for x in weights]
        s = sum(w)
        if parts <= 0:
            return []
        if s <= 0 or not all(math.isfinite(x) for x in w):
            return ShardedSampler.even_split(total, parts)
        raw = [total * x / s for x in w]
        out = [int(math.floor(r)) for r in raw]
        if total >= parts:
            out = [max(1, v) for v in out]
        short = total - sum(out)
        if short > 0:
            order = sorted(range(parts), key=lambda i: raw[i] - out[i],
                           reverse=True)
            for i in range(short):
                out[order[i % parts]] += 1
        while short < 0:
            # min-1 clamping overshot: shave the largest parts back down
            j = max(range(parts), key=lambda i: out[i])
            if out[j] <= (1 if total >= parts else 0):
                break
            out[j] -= 1
            short += 1
        return out

    def _per_host_consensus(self, reason: str) -> Optional[Dict[str, Any]]:
        """Per-host (non-uniform) consensus: every host runs its own DPT
        sweep, adopts its own optimal (nWorker, nPrefetch), and the batch
        partition is re-apportioned so faster hosts take proportionally
        larger contiguous host-major slices (weights = measured samples/s
        at each host's optimum).  The partition lands fleet-wide through
        the same barrier protocol as a membership reshard — a partition-
        only change is safe at any common batch boundary."""
        hosts = sorted(h for h in self.agents
                       if h in set(self.registry.alive_hosts()))
        if not hosts:
            return None
        agents = [self.agents[h] for h in hosts]
        tuner = MultiHostDPT([a.evaluator for a in agents],
                             self._search_config())
        self._last_consensus_step = self.fleet_step
        for a in agents:
            a.begin_trials()
        try:
            fleet = tuner.run_per_host()
        except MemoryOverflow:
            self._backoff = min(self.cfg.max_backoff, self._backoff * 2)
            return None
        finally:
            for a in agents:
                a.end_trials()
        self.consensus_runs += 1
        by_shard = sorted(agents, key=lambda a: a.shard_index())
        order = {a.host: i for i, a in enumerate(by_shard)}
        gb = by_shard[0].global_batch()
        cur_sizes = by_shard[0].shard_sizes() \
            or ShardedSampler.even_split(gb, len(by_shard))
        # rate_i = local_i / optimal_time_i — what host i demonstrably
        # moves per second at its own optimum under its CURRENT slice
        rates = [0.0] * len(by_shard)
        for a, r in zip(agents, fleet.per_host):
            rates[order[a.host]] = (
                cur_sizes[order[a.host]] / r.optimal_time
                if r.optimal_time > 0 and math.isfinite(r.optimal_time)
                else 0.0)
        sizes = self._apportion(gb, rates)
        sizes_changed = sizes != cur_sizes
        cells_changed = any(
            (r.nworker, r.nprefetch) != a.param_cell()
            for a, r in zip(agents, fleet.per_host))
        applied = cells_changed or sizes_changed
        self._backoff = 1 if applied else min(self.cfg.max_backoff,
                                              self._backoff * 2)
        params_by_host = {a.host: (r.nworker, r.nprefetch)
                          for a, r in zip(agents, fleet.per_host)}
        event = {"kind": "consensus", "mode": "per_host", "reason": reason,
                 "params": [params_by_host[a.host] for a in by_shard],
                 "fleet_time": fleet.fleet_time, "hosts": hosts,
                 "sizes": sizes if sizes_changed else None,
                 "cell_applied": cells_changed, "applied": applied}
        if cells_changed:
            for a in agents:
                nw, npf = params_by_host[a.host]
                a.apply_params(nw, npf)
        if sizes_changed:
            rid = self.reshards
            event["barrier"] = self._negotiate_barrier(
                by_shard, len(by_shard), 0, rid=rid, sizes=sizes)
            self.reshards += 1
        self.events.append(event)
        if applied:
            self._pushed = {"cell": None,
                            "schedule": to_wire(agents[0].schedule_state())}
        self._checkpoint()
        return event

    @staticmethod
    def _current_cells(agents: Sequence[Any]) -> Dict[Tuple[int, int], int]:
        counts: Dict[Tuple[int, int], int] = {}
        for a in agents:
            key = a.param_cell()
            counts[key] = counts.get(key, 0) + 1
        return counts

    @classmethod
    def _majority_cell(cls, agents: Sequence[Any]) -> Tuple[int, int]:
        counts = cls._current_cells(agents)
        return max(counts, key=counts.get)

    def _locality_consensus(self, agents: Sequence[HostAgent],
                            cell: Tuple[int, int]) -> Optional[int]:
        """Uniform locality decision: per-host chunk sweeps at ``cell``,
        aggregated by the fleet max; the winner must beat the current
        chunk's own fleet time by ``min_improvement`` and be feasible on
        every host.  Returns the winning chunk or None (keep)."""
        if not self.cfg.locality_chunks:
            return None
        from repro.tuning.locality import sweep_locality
        cfg = self._search_config()
        cur = agents[0].knob_state()["locality_chunk"]
        for a in agents:
            a.begin_trials()
        try:
            per_host = [sweep_locality(
                a.evaluator, nworker=cell[0], nprefetch=cell[1],
                chunks=self.cfg.locality_chunks, current_chunk=cur,
                num_batches=cfg.num_batches) for a in agents]
        finally:
            for a in agents:
                a.end_trials()
        fleet_time: Dict[int, float] = {}
        for trials in per_host:
            for chunk, t in trials.items():
                fleet_time[chunk] = max(fleet_time.get(chunk, 0.0),
                                        t.seconds)
        feasible = {c: s for c, s in fleet_time.items()
                    if math.isfinite(s)}
        if not feasible:
            return None
        best = min(feasible, key=feasible.get)
        if best == cur:
            return None
        if cur not in feasible:
            return best                   # current chunk infeasible somewhere
        if feasible[best] <= (1.0 - self.cfg.min_improvement) * feasible[cur]:
            return best
        return None

    def _cache_consensus(self, agents: Sequence[HostAgent],
                         cell: Tuple[int, int]) -> Optional[int]:
        """Uniform cache-budget decision (DESIGN.md §7): per-host budget
        sweeps at ``cell`` measured at a WARM epoch (a cross-epoch cache
        prices at 0 cold), aggregated by the fleet max; the winner must
        beat the current budget's own fleet time by ``min_improvement``
        and be feasible on every host.  Returns the winning budget or
        None (keep)."""
        if not self.cfg.cache_budgets:
            return None
        from repro.tuning.locality import sweep_cache
        cfg = self._search_config()
        cur = agents[0].knob_state()["cache_budget_bytes"]
        for a in agents:
            a.begin_trials()
        try:
            per_host = [sweep_cache(
                a.evaluator, nworker=cell[0], nprefetch=cell[1],
                budgets=self.cfg.cache_budgets, current_budget=cur,
                num_batches=cfg.num_batches,
                epoch=max(1, cfg.epoch)) for a in agents]
        finally:
            for a in agents:
                a.end_trials()
        fleet_time: Dict[int, float] = {}
        for trials in per_host:
            for budget, t in trials.items():
                fleet_time[budget] = max(fleet_time.get(budget, 0.0),
                                         t.seconds)
        feasible = {b: s for b, s in fleet_time.items()
                    if math.isfinite(s)}
        if not feasible:
            return None
        best = min(feasible, key=feasible.get)
        if best == cur:
            return None
        if cur not in feasible:
            return best                  # current budget infeasible somewhere
        if feasible[best] <= (1.0 - self.cfg.min_improvement) * feasible[cur]:
            return best
        return None

    def _is_fleet_win(self, fleet, agents: Sequence[HostAgent]) -> bool:
        """Anti-churn at fleet scope: the uniform winner must differ from
        the current (majority) config and beat that config's own measured
        fleet time by ``min_improvement``."""
        current = self._current_cells(agents)
        cur_cell = max(current, key=current.get)
        if fleet.uniform_params == cur_cell and len(current) == 1:
            return False
        cur_times = []
        for r in fleet.per_host:
            t = next((t for t in r.trials
                      if (t.nworker, t.nprefetch) == cur_cell
                      and math.isfinite(t.seconds)), None)
            if t is None:
                return True          # current cell infeasible somewhere
            cur_times.append(t.seconds)
        cur_fleet = max(cur_times)
        return fleet.fleet_time \
            <= (1.0 - self.cfg.min_improvement) * cur_fleet

    # ---- act: elastic reshard ----------------------------------------------
    def _reshard_around(self, hosts: Sequence[str], *,
                        reason: str) -> Dict[str, Any]:
        """One or more hosts left the fleet (a rack failure is one event,
        not a cascade): remap every survivor at one common barrier and
        redistribute every departed host's undelivered slices.

        Crash-safe in HA mode: a write-ahead intent (lost hosts, their
        frozen consumed positions + member mirrors) is checkpointed
        BEFORE any command goes out, and again with the settled barrier +
        computed makeup shares before any share is dealt — a promoted
        standby replays the remainder with the SAME stable op-ids, which
        the agents' dedup turns into exactly-once application.
        """
        departed = [self.agents.pop(h) for h in hosts]
        for h in hosts:
            self.registry.remove(h)
            self.straggler.forget(h)
            self.reports.pop(h, None)
        rid = self.reshards
        consumed = {d.host: d.consumed_position() for d in departed}
        self._pending_reshard = {
            "rid": rid, "reason": reason, "stage": "begin",
            "lost": list(hosts), "consumed": dict(consumed),
            "departed": {d.host: d.ha_state() for d in departed}}
        self._checkpoint()
        return self._execute_reshard(departed, consumed,
                                     reason=reason, rid=rid)

    def _execute_reshard(self, departed: Sequence[Any],
                         consumed: Dict[str, int], *, reason: str,
                         rid: int) -> Dict[str, Any]:
        hosts = [d.host for d in departed]
        # survivors keep their relative order; shard indices compact
        survivors = sorted(self.agents.values(),
                           key=lambda a: a.shard_index())
        new_count = len(survivors)
        old_count = new_count + len(departed)
        event: Dict[str, Any] = {"kind": "reshard", "reason": reason,
                                 "lost": list(hosts), "host": hosts[0],
                                 "dead_consumed": consumed,
                                 "hosts": new_count}
        if not survivors:
            event.update(barrier=None, makeup_batches=0, plan=None)
            self.events.append(event)
            self._pending_reshard = None
            self._checkpoint()
            return event
        # the surviving hosts keep the OLD global batch until the geometry
        # latch below; when it does not divide the survivor count the
        # partition must go ragged (even_split) or the reshard would have
        # silently truncated samples (old bug: floor division dropped
        # global_batch % new_count samples from every batch)
        old_gb = survivors[0].global_batch()
        sizes: Optional[List[int]] = None
        if old_gb % new_count:
            sizes = ShardedSampler.even_split(old_gb, new_count)
        barrier = self._negotiate_barrier(
            survivors, new_count, max(consumed.values(), default=0),
            rid=rid, sizes=sizes)
        plan = plan_remesh(
            alive_hosts=new_count,
            devices_per_host=self.cfg.devices_per_host,
            model_axis=self.cfg.model_axis,
            old_hosts=old_count,
            old_global_batch=departed[0].global_batch(),
            restore_step=barrier)
        # elastic geometry: the plan's new_global_batch latches at the
        # next epoch boundary no survivor has entered yet (geometry moves
        # shard boundaries, so mid-epoch application would break exact
        # coverage; the ragged sizes above bridge the mid-epoch tail).
        # The latch epoch is FROZEN into the WAL before any host is
        # pushed: a replay after a partial push must re-issue the same
        # epoch everywhere or hosts would latch on divergent boundaries.
        geometry: Optional[Dict[str, int]] = None
        if (self.cfg.elastic_geometry and plan.feasible
                and plan.new_global_batch != old_gb):
            geometry = {
                "global_batch": int(plan.new_global_batch),
                "epoch": max(a.locality_latch_epoch() for a in survivors)}
        # makeup: every departed host's undelivered slices up to the
        # settled barrier, PLUS any makeup chunks a previous reshard dealt
        # to it that it never delivered (makeup parked on a corpse is
        # otherwise lost), re-chunked to each recipient's NEW local batch
        # size (so the chunks share the regular batch shape and can use
        # the re-specced arena; at most one ragged tail chunk bypasses
        # it) and dealt round-robin over survivors
        missing: List[np.ndarray] = []
        makeup_batches = 0
        for d in departed:
            for b in range(consumed[d.host], barrier):
                missing.append(d.local_indices_at(b))
                makeup_batches += 1
            inherited = d.undelivered_makeup()
            missing.extend(inherited)
            makeup_batches += len(inherited)
        shares: List[List[np.ndarray]] = [[] for _ in survivors]
        if missing:
            flat = np.concatenate(missing)
            local = (sizes if sizes is not None
                     else [old_gb // new_count] * new_count)
            pos, k = 0, 0
            while pos < len(flat):
                take = local[k % new_count]
                if take > 0:
                    shares[k % new_count].append(flat[pos:pos + take])
                    pos += take
                k += 1
        event.update(barrier=barrier, makeup_batches=makeup_batches,
                     plan=plan, sizes=sizes,
                     geometry_epoch=None if geometry is None
                     else geometry["epoch"])
        if self._pending_reshard is not None:
            self._pending_reshard.update(
                stage="deal", barrier=barrier, geometry=geometry,
                shares={a.host: [c.tolist() for c in share]
                        for a, share in zip(survivors, shares) if share},
                dealt=[],
                event=to_wire({**event, "plan": dataclasses.asdict(plan)}))
            self._checkpoint()
        if geometry is not None:
            for a in survivors:
                a.set_geometry(geometry["global_batch"],
                               epoch=geometry["epoch"],
                               op_id=f"reshard-{rid}-geom-{a.host}")
        self._deal_makeup(
            {a.host: share for a, share in zip(survivors, shares) if share},
            rid=rid)
        self.reshards += 1
        # the per-host optimum moved with the local batch size: follow the
        # reshard with a re-consensus for the new topology at next poll
        if self._forced_reason is None:
            self._forced_reason = "post-reshard"
        self.events.append(event)
        self._pending_reshard = None
        self._checkpoint()
        return event

    def _deal_makeup(self, shares: Dict[str, List[np.ndarray]], *,
                     rid: int) -> None:
        for host, share in shares.items():
            agent = self.agents.get(host)
            if agent is None:
                continue
            agent.add_makeup(share, op_id=f"reshard-{rid}-makeup-{host}")
            if self._pending_reshard is not None:
                self._pending_reshard["dealt"].append(host)
                self._checkpoint()

    # ---- survivability: snapshot / restore / replay ------------------------
    def _checkpoint(self) -> None:
        """Publish the full decide-state to the snapshot store (no-op in
        direct mode) — called on every state transition so a standby can
        resume from the last completed step."""
        if self._store is not None:
            self._store.put(self.state_dict())

    def state_dict(self) -> Dict[str, Any]:
        """Everything a standby needs to BE this coordinator: consensus
        history + backoff, heartbeat registry, straggler windows, the
        stale-report guard, member mirrors + dealt-makeup logs, the
        bounded event log (with its fleet-lifetime seq), the last uniform
        push, and any pending (write-ahead) reshard intent."""
        return to_wire({
            "config": dataclasses.asdict(self.cfg),
            "members": {h: a.ha_state() for h, a in self.agents.items()},
            "reports": {h: report_to_wire(r)
                        for h, r in self.reports.items()},
            "last_steps": dict(self._last_steps),
            "heartbeats": self.registry.state_dict(),
            "straggler": self.straggler.state_dict(),
            "events": self.events.state_dict(),
            "counters": {"consensus_runs": self.consensus_runs,
                         "reshards": self.reshards,
                         "last_consensus_step": self._last_consensus_step,
                         "backoff": self._backoff,
                         "forced_reason": self._forced_reason,
                         "stale_reports": self.stale_reports,
                         "fleet_faulted": self._fleet_faulted},
            "pushed": self._pushed,
            "pending_reshard": self._pending_reshard})

    @classmethod
    def restore(cls, state: Dict[str, Any], *,
                clock: Callable[[], float] = time.monotonic
                ) -> "FleetCoordinator":
        """Rebuild a coordinator from a snapshot.  Member proxies are
        materialized when a CoordinatorServer binds (they need a wire to
        speak through); until then membership lives in ``_member_state``.
        Historical events restore as plain dicts (ElasticPlan values
        become dicts — they are records, not live objects)."""
        cfgd = dict(state["config"])
        for k in ("locality_chunks", "cache_budgets"):
            if cfgd.get(k) is not None:
                cfgd[k] = tuple(cfgd[k])
        c = cls(config=FleetConfig(**cfgd), clock=clock)
        c._member_state = dict(state.get("members") or {})
        c.reports = {h: report_from_wire(r)
                     for h, r in (state.get("reports") or {}).items()}
        c._last_steps = {h: int(v)
                         for h, v in (state.get("last_steps") or {}).items()}
        c.registry.load_state(state.get("heartbeats") or {})
        c.straggler.load_state(state.get("straggler") or {})
        c.events = EventLog.restore(state.get("events") or {})
        counters = state.get("counters") or {}
        c.consensus_runs = int(counters.get("consensus_runs", 0))
        c.reshards = int(counters.get("reshards", 0))
        c._last_consensus_step = int(counters.get("last_consensus_step", 0))
        c._backoff = int(counters.get("backoff", 1))
        c._forced_reason = counters.get("forced_reason")
        c.stale_reports = int(counters.get("stale_reports", 0))
        c._fleet_faulted = bool(counters.get("fleet_faulted", False))
        c._pushed = state.get("pushed")
        c._pending_reshard = state.get("pending_reshard")
        return c

    def _bind_server(self, server: "CoordinatorServer") -> None:
        """Attach the message server: restore-time members materialize as
        RemoteAgent proxies, and heartbeats are re-armed at NOW so a
        failover gap longer than the timeout does not insta-kill every
        host (a truly dead host simply times out once more)."""
        self._server = server
        self._store = server.store
        if self._member_state is not None:
            for host, ms in self._member_state.items():
                proxy = RemoteAgent.restore(server, ms)
                proxy.coordinator = self
                self.agents[host] = proxy
            self._member_state = None
        self.registry.rearm(list(self.agents))

    def _resume_reshard(self) -> Optional[Dict[str, Any]]:
        """Replay a reshard the previous leader died inside (promotion
        path).  stage="begin": nothing was dealt — run it from the frozen
        intent.  stage="deal": the barrier settled and shares froze —
        re-deal only the un-acked shares under their original op-ids."""
        pr = self._pending_reshard
        if not pr or self._server is None:
            return None
        rid = int(pr["rid"])
        consumed = {h: int(v) for h, v in pr["consumed"].items()}
        departed = [RemoteAgent.restore(self._server, ms)
                    for ms in pr["departed"].values()]
        if pr.get("stage") == "begin":
            return self._execute_reshard(
                departed, consumed,
                reason=str(pr["reason"]) + "+replay", rid=rid)
        # stage == "deal"
        geometry = pr.get("geometry")
        if geometry is not None:
            # re-issue under the ORIGINAL frozen latch epoch and op-ids:
            # hosts already pushed dedupe on the op-id, the rest latch at
            # the same boundary the dead leader chose
            for a in sorted(self.agents.values(), key=lambda x: x.host):
                a.set_geometry(int(geometry["global_batch"]),
                               epoch=int(geometry["epoch"]),
                               op_id=f"reshard-{rid}-geom-{a.host}")
        dealt = set(pr.get("dealt") or [])
        shares = {h: [np.asarray(c, dtype=np.int64) for c in share]
                  for h, share in (pr.get("shares") or {}).items()
                  if h not in dealt}
        self._deal_makeup(shares, rid=rid)
        self.reshards += 1
        if self._forced_reason is None:
            self._forced_reason = "post-reshard"
        event = dict(pr.get("event") or {})
        event["reason"] = str(event.get("reason", "")) + "+replay"
        self.events.append(event)
        self._pending_reshard = None
        self._checkpoint()
        return event


# --------------------------------------------------------------------------
# the coordinator's message server + the standby replica
# --------------------------------------------------------------------------
class CoordinatorServer:
    """Binds a FleetCoordinator to a transport endpoint.

    Inbound: registration/join, (delta-encoded) reports, beats, drift and
    locality casts.  Outbound: every command the decide loop issues goes
    through :meth:`send`, stamped with the leader's fence token and a
    unique op-id — an agent that has seen a newer fence rejects the
    command (:class:`StaleLeaderError` marks this server deposed).

    Report handling keeps the per-host delta base server-side only: after
    a failover the new server simply answers ``need_full`` once and the
    protocol self-heals.  Reconnecting hosts are caught up from the
    coordinator's ``_pushed`` record (cell re-push + schedule sync).
    """

    def __init__(self, coord: FleetCoordinator, transport: LocalTransport, *,
                 name: str = "coord", owner: str = "coord-0",
                 lease: Optional[LeaderLease] = None,
                 store: Optional[SnapshotStore] = None,
                 retries: int = 6):
        self.coord = coord
        self.transport = transport
        self.name = name
        self.owner = owner
        self.lease = lease
        self.store = store
        self.retries = max(1, retries)
        self.fence = 0 if lease is None else (lease.acquire(owner) or 0)
        self.deposed = False
        self.crashed = False
        self._cmd_seq = 0
        self._last_full: Dict[str, Dict[str, Any]] = {}
        # traffic accounting for the O(hosts) heartbeat assertion
        self.report_full_msgs = 0
        self.report_full_bytes = 0
        self.report_delta_msgs = 0
        self.report_delta_bytes = 0
        transport.register(name, self.handle, replace=True)
        coord._bind_server(self)
        coord._checkpoint()

    # ---- leadership --------------------------------------------------------
    def tick(self) -> None:
        """Refresh the lease + checkpoint — the leader's heartbeat."""
        if self.crashed or self.deposed:
            return
        if self.lease is not None and not self.lease.refresh(self.owner):
            self.deposed = True
            return
        self.coord._checkpoint()

    def crash(self) -> None:
        """Simulated leader death: endpoint gone, lease left to expire."""
        self.crashed = True
        self.transport.unregister(self.name)

    def poll(self) -> List[Dict[str, Any]]:
        """Drive the decide loop, absorbing deposition: a stale-fence
        rejection anywhere inside means a newer leader owns the fleet —
        this one stops acting instead of fighting."""
        if self.crashed or self.deposed:
            return []
        try:
            actions = self.coord.poll()
        except StaleLeaderError:
            self.deposed = True
            return []
        self.coord._checkpoint()
        return actions

    # ---- outbound ----------------------------------------------------------
    def send(self, host: str, op: str, args: Dict[str, Any], *,
             op_id: Optional[str] = None) -> Any:
        self._cmd_seq += 1
        msg = {"kind": "cmd", "op": op, "args": to_wire(args),
               "fence": self.fence,
               "id": op_id or f"f{self.fence}-c{self._cmd_seq}"}
        last_err: Optional[str] = None
        for _ in range(self.retries):
            try:
                reply = self.transport.call(self.name, host, msg)
            except TransportError as e:
                last_err = str(e)
                continue
            if reply.get("ok"):
                return reply.get("result")
            err = str(reply.get("error", ""))
            if err == "stale-fence":
                self.deposed = True
                raise StaleLeaderError(
                    f"{self.name}(fence={self.fence}) deposed: {host} has "
                    f"seen fence {reply.get('fence')}")
            last_err = err
        raise TransportError(
            f"{self.name} -> {host}: {op} failed after "
            f"{self.retries} attempts ({last_err})")

    # ---- inbound -----------------------------------------------------------
    def handle(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        kind = msg.get("kind")
        host = str(msg.get("host", "?"))
        if kind == "report":
            return self._handle_report(host, msg)
        if kind == "beat":
            if host in self.coord.agents:
                self.coord.beat(host)
                return {"ok": True, "fence": self.fence}
            return {"ok": False, "evicted": True, "fence": self.fence}
        if kind == "register":
            proxy = RemoteAgent(self, msg["spec"])
            self.coord.register(proxy)
            self._last_full.pop(host, None)
            self.coord._checkpoint()
            return {"ok": True, "fence": self.fence}
        if kind == "join":
            proxy = RemoteAgent(self, msg["spec"])
            barrier = self.coord.join(proxy)
            self._last_full.pop(host, None)
            return {"ok": True, "fence": self.fence, "barrier": barrier}
        if kind == "leave":
            if host in self.coord.agents:
                self.coord.leave(host)
            return {"ok": True, "fence": self.fence}
        if kind == "drift":
            self.coord.request_consensus(
                reason=str(msg.get("reason", "drift")))
            return {"ok": True, "fence": self.fence}
        if kind == "locality":
            self.coord.request_locality(int(msg.get("chunk", 0)), host=host)
            return {"ok": True, "fence": self.fence}
        if kind == "ping":
            return {"ok": True, "fence": self.fence}
        return {"ok": False, "error": f"unknown kind {kind!r}",
                "fence": self.fence}

    def _handle_report(self, host: str,
                       msg: Dict[str, Any]) -> Dict[str, Any]:
        from repro.tuning.transport import (merge_report_delta,
                                            payload_bytes)
        proxy = self.coord.agents.get(host)
        if proxy is None:
            # resharded around during a partition: the host's shard no
            # longer exists — tell it so it can stop and (re)join
            return {"ok": False, "evicted": True, "fence": self.fence}
        if msg.get("delta"):
            base = self._last_full.get(host)
            if base is None or int(base.get("steps", -1)) \
                    != int(msg.get("base", -2)):
                return {"ok": False, "need_full": True, "fence": self.fence}
            fulls = [merge_report_delta(base, msg.get("patch") or {})]
            self.report_delta_msgs += 1
            self.report_delta_bytes += payload_bytes(msg)
        else:
            fulls = list(msg.get("reports") or [])
            self.report_full_msgs += 1
            self.report_full_bytes += payload_bytes(msg)
        accepted_any = False
        last_steps = -1
        for f in fulls:
            r = report_from_wire(f)
            if self.coord.ingest(r):
                accepted_any = True
                self._last_full[host] = {k: v for k, v in f.items()}
                if hasattr(proxy, "observe_report"):
                    proxy.observe_report(r, f.get("schedules"))
            last_steps = max(last_steps, r.steps)
        reply = {"ok": True, "fence": self.fence, "steps": last_steps}
        if accepted_any:
            self._catch_up(proxy)
        return reply

    def _catch_up(self, proxy: Any) -> None:
        """Schedule catch-up for a host that missed pushes while
        partitioned: re-issue the last uniform cell and/or schedules when
        the host's reported state disagrees with what the fleet runs."""
        pushed = self.coord._pushed
        if not pushed or not hasattr(proxy, "param_cell"):
            return
        try:
            cell = pushed.get("cell")
            if cell is not None and tuple(cell) != proxy.param_cell():
                proxy.apply_params(int(cell[0]), int(cell[1]))
            sched = pushed.get("schedule")
            if sched is not None:
                mine = to_wire(proxy.schedule_state())
                if (mine.get("locality"), mine.get("cache")) != \
                        (sched.get("locality"), sched.get("cache")):
                    proxy.sync_schedules(sched)
        except TransportError:
            pass        # still flaky — the next accepted report retries


class CoordinatorReplica:
    """A standby coordinator: watches the lease, and when the primary's
    lease expires, acquires it (fence bump), restores the last snapshot,
    takes over the transport endpoint and replays any pending reshard.
    The promotion is the failover state machine's only transition:
    standby -> leader; a deposed old leader discovers its fate through
    stale-fence rejections."""

    def __init__(self, transport: LocalTransport, lease: LeaderLease,
                 store: SnapshotStore, *, owner: str = "coord-standby",
                 name: str = "coord",
                 clock: Callable[[], float] = time.monotonic):
        self.transport = transport
        self.lease = lease
        self.store = store
        self.owner = owner
        self.name = name
        self.clock = clock
        self.server: Optional[CoordinatorServer] = None
        self.promoted = False

    def tick(self) -> Optional[CoordinatorServer]:
        """Returns the new server on the tick that promotes, else None."""
        if self.promoted:
            return None
        if self.lease.holder() is not None:
            return None                       # primary still refreshing
        state = self.store.get()
        if state is None:
            return None
        fence = self.lease.acquire(self.owner)
        if fence is None:
            return None
        coord = FleetCoordinator.restore(state, clock=self.clock)
        server = CoordinatorServer(coord, self.transport, name=self.name,
                                   owner=self.owner, lease=self.lease,
                                   store=self.store)
        server.fence = fence
        coord.events.append({"kind": "promote", "owner": self.owner,
                             "fence": fence})
        # replay any reshard the old leader died inside; a host that is
        # unreachable RIGHT NOW must not fail the promotion — the intent
        # stays write-ahead-logged and the new leader's poll resumes it
        coord._absorb_transport(coord._resume_reshard)
        coord._checkpoint()
        self.server = server
        self.promoted = True
        return server


def connect_host(transport: LocalTransport, host: str, loader: DataLoader, *,
                 evaluator=None, coord: str = "coord",
                 link_config: Optional["LinkConfig"] = None,
                 clock: Callable[[], float] = time.monotonic,
                 join: bool = False, consumes_stream: bool = True,
                 **agent_kw: Any) -> HostAgent:
    """Construct a transport-attached :class:`HostAgent` and announce it.

    The one-call fleet entry point for Trainer/serving hosts:
    ``register`` (fleet start) or ``join=True`` (mid-run admission —
    incumbents reshard and this host aligns at the returned barrier).
    Raises :class:`TransportError` when the coordinator is unreachable
    after retries — admission is the only send that may block/raise; all
    steady-state traffic after this is fire-and-forget."""
    from repro.tuning.transport import LinkConfig as _LinkConfig
    link = AgentLink(transport, host, coord=coord,
                     config=link_config or _LinkConfig(), clock=clock)
    agent = HostAgent(host, loader, evaluator=evaluator, link=link,
                      consumes_stream=consumes_stream, **agent_kw)
    (link.join if join else link.register)()
    return agent
