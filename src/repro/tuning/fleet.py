"""Fleet control plane: coordinated per-host online tuning + elastic
resharding of the live data pipeline.

The single-host :class:`~repro.tuning.online.OnlineTuner` observes,
decides and acts on one machine.  A fleet serving heavy traffic needs the
same loop split across the wire: per-host optima diverge with hardware,
hosts drift, straggle and die, and a lockstep SPMD fleet's effective
transfer time is the MAX over hosts — so per-host decisions must be
coordinated to protect global goodput.

  observe — a :class:`HostAgent` on every host feeds its
            :class:`GoodputMonitor` one (data-wait, step-time) pair per
            step and streams :class:`HostReport`\\ s (goodput, stall
            ratio, per-batch seconds, stream position) to the
            coordinator.  Each ingested report is also the host's
            heartbeat.
  decide  — the :class:`FleetCoordinator` aggregates: fleet-level stall
            drift or straggler divergence declares a re-consensus;
            heartbeat timeouts declare a death; ``join`` admits a new
            host.  Warmup/cooldown/backoff bookkeeping lives here, not on
            the hosts.
  act     — re-consensus runs the existing ``tune()``/:class:`MultiHostDPT`
            machinery over every live host's evaluator and hot-swaps the
            winning uniform params into each host through
            ``apply_params``.  A death (or join) emits an elastic
            reshard: every surviving loader remaps its
            ``ShardedSampler`` shard at a common global-batch barrier,
            and the dead host's undelivered slices are redistributed as
            makeup chunks — zero samples lost, zero duplicated across
            the transition (see ``LoaderStream.apply_reshard``).

Reshard invariants (DESIGN.md §4):

* the global permutation and global-batch boundaries depend only on
  (seed, epoch, global_batch) — never on the shard topology;
* all hosts remap at the SAME absolute barrier ``B``, chosen as the max
  stream position over survivors (no host has yielded past it);
* batches before ``B`` were delivered under the old shard map (the dead
  host's own deliveries up to its last reported position included),
  batches from ``B`` on are delivered under the new map, and the dead
  host's undelivered window ``[dead_position, B)`` arrives as makeup —
  the union is every index exactly once per epoch.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dpt import DPTConfig, DPTResult, MultiHostDPT
from repro.core.monitor import MemoryOverflow
from repro.data.loader import DataLoader, LoaderParams
from repro.distributed.fault_tolerance import (HeartbeatRegistry,
                                               StragglerDetector, plan_remesh)
from repro.tuning.base import adaptive_budget
from repro.tuning.online import GoodputMonitor


# --------------------------------------------------------------------------
# consensus math (MultiHostDPT.run_uniform delegates here)
# --------------------------------------------------------------------------
def uniform_consensus(results: Sequence[DPTResult]
                      ) -> Tuple[Tuple[int, int], float]:
    """Straggler-aware minimax over per-host sweeps.

    Candidate cells are every host's trials, scored by the fleet max (the
    lockstep step time); a cell is feasible only if every host measured it
    un-overflowed.  Returns the argmin cell and its fleet time; raises
    MemoryOverflow when no cell is feasible everywhere.
    """
    per_cell: Dict[Tuple[int, int], float] = {}
    counts: Dict[Tuple[int, int], int] = {}
    for r in results:
        for t in r.trials:
            key = (t.nworker, t.nprefetch)
            per_cell[key] = max(per_cell.get(key, 0.0), t.seconds)
            if not t.overflowed and math.isfinite(t.seconds):
                counts[key] = counts.get(key, 0) + 1
    feasible = {k: v for k, v in per_cell.items()
                if counts.get(k, 0) == len(results)}
    if not feasible:
        raise MemoryOverflow("no uniform cell feasible on all hosts")
    best = min(feasible, key=feasible.get)
    return best, feasible[best]


# --------------------------------------------------------------------------
# the wire format
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HostReport:
    """One observation snapshot from a host (also its heartbeat)."""
    host: str
    steps: int                       # observations since the agent started
    consumed: int                    # absolute global-batch position trained
    position: int                    # stream yield cursor (>= consumed)
    stall_ratio: float
    steps_per_s: float
    batch_seconds: List[float]
    params: Tuple[int, int]          # current (num_workers, prefetch_factor)
    # IO-efficiency snapshot (DataLoader.io_counters: storage request
    # counters, achieved coalesced run length, staging/arena hit rates) —
    # lets retune decisions and dashboards see *locality*, not just rates.
    # None when nothing in the host's pipeline keeps counters.
    io: Optional[Dict[str, float]] = None


@dataclasses.dataclass
class FleetConfig:
    heartbeat_timeout_s: float = 30.0
    # decide: aggregate drift + straggler divergence
    stall_fraction: float = 0.35     # mean stall ratio over alive hosts
    straggler_threshold: float = 1.5
    straggler_window: int = 16
    warmup_steps: int = 4            # min fleet steps before deciding
    cooldown_steps: int = 16         # fleet steps between consensus runs
    max_backoff: int = 8
    min_improvement: float = 0.05    # uniform winner must beat current cell
    # act: the consensus search (None budget derives adaptively)
    retune_budget_batches: Optional[int] = None
    max_prefetch: int = 4
    num_cpu_cores: Optional[int] = None
    num_devices: Optional[int] = None
    # online locality axis (DESIGN.md §6): candidate sampler chunk sizes a
    # re-consensus may propose.  Locality can only change UNIFORMLY on a
    # sharded fleet (every host must slice the same epoch permutation), so
    # the sweep scores candidates by the fleet max and the push pins one
    # common latch epoch on every host.  None keeps re-consensus on
    # (workers, prefetch).
    locality_chunks: Optional[Tuple[int, ...]] = None
    # online cache axis (DESIGN.md §7): candidate cross-epoch cache budgets
    # a re-consensus may propose.  The budget changes UNIFORMLY too — not
    # for correctness (each host's tier only serves its own shard) but for
    # goodput: a lockstep fleet runs at the max host time, so a budget only
    # helps when every host carries it.  Scored by the fleet max at a warm
    # epoch; None keeps re-consensus off the axis.
    cache_budgets: Optional[Tuple[int, ...]] = None
    # elastic re-mesh bookkeeping (plan_remesh)
    devices_per_host: int = 1
    model_axis: int = 1


# --------------------------------------------------------------------------
# per-host agent: observe + act, no decisions
# --------------------------------------------------------------------------
class HostAgent:
    """The fleet's presence on one host.

    Observe: ``observe(data_s, step_s)`` once per training/serving step —
    it feeds the goodput window and streams a report (the heartbeat) to
    the coordinator.  Act: ``apply_params`` / ``reshard`` are invoked BY
    the coordinator; the agent never decides anything itself.
    """

    def __init__(self, host: str, loader: DataLoader, *, evaluator=None,
                 window: int = 8, report_every: int = 1,
                 consumes_stream: bool = True):
        self.host = host
        self.loader = loader
        if evaluator is None:
            from repro.core.evaluators import LoaderEvaluator
            evaluator = LoaderEvaluator(loader, to_device=True)
        self.evaluator = evaluator
        self.monitor = GoodputMonitor(window=window)
        self.report_every = max(1, report_every)
        # training loops consume exactly one loader batch per observe();
        # serving frontends observe per served request-group instead, so
        # their step count says nothing about loader consumption — they
        # pass consumes_stream=False and the stream cursor is used
        self.consumes_stream = consumes_stream
        self.coordinator: Optional["FleetCoordinator"] = None
        bpe = loader.sampler.batches_per_epoch()
        self._base = loader.sampler.state.absolute(bpe)
        self.steps = 0
        # which live stream the consumed-step count refers to: makeup
        # yields do not advance the regular-batch position, so the count
        # must be mapped through the stream's per-yield position log
        # rather than added to a base (see LoaderStream.position_after)
        self._consume_stream = None
        self._bind_steps = 0

    # ---- observe -----------------------------------------------------------
    def observe(self, *, data_s: float, step_s: float) -> None:
        self.monitor.observe(data_s=data_s, step_s=step_s)
        self.steps += 1
        if self.consumes_stream:
            stream = self.loader._live_stream
            if stream is not None and stream is not self._consume_stream:
                # first observe against a (re)built stream: the batch just
                # consumed was that stream's first consumed yield
                self._consume_stream = stream
                self._bind_steps = self.steps - 1
        if self.coordinator is not None \
                and self.steps % self.report_every == 0:
            self.coordinator.ingest(self.report())

    def consumed_position(self) -> int:
        """Absolute global-batch position the CONSUMER reached (one stream
        yield per observed step for a training loop — mapped through the
        stream's position log because makeup yields do not advance the
        position; the stream cursor when the observer does not consume
        the stream batch-per-step)."""
        if not self.consumes_stream:
            return self.stream_position()
        stream = self._consume_stream
        if stream is not None and stream is self.loader._live_stream:
            return stream.position_after(self.steps - self._bind_steps)
        return self._base + self.steps

    def stream_position(self) -> int:
        """The live stream's yield cursor (>= consumed: the device
        prefetcher may hold yielded-but-unconsumed batches, which are
        guaranteed to be delivered)."""
        stream = self.loader._live_stream
        if stream is not None:
            return stream.position
        return self.loader.sampler.state.absolute(
            self.loader.sampler.batches_per_epoch())

    def report(self) -> HostReport:
        p = self.loader.params
        return HostReport(
            host=self.host, steps=self.steps,
            consumed=self.consumed_position(),
            position=self.stream_position(),
            stall_ratio=self.monitor.stall_ratio,
            steps_per_s=self.monitor.steps_per_s,
            batch_seconds=self.monitor.batch_seconds,
            params=(p.num_workers, p.prefetch_factor),
            io=self.loader.io_counters() or None)

    def heartbeat(self) -> None:
        """Liveness without an observation (e.g. a serving frontend between
        batches)."""
        if self.coordinator is not None:
            self.coordinator.beat(self.host)

    def notify_drift(self, reason: str) -> None:
        """External drift signal (e.g. the serving batch-mix monitor):
        asks the coordinator for an out-of-band re-consensus."""
        if self.coordinator is not None:
            self.coordinator.request_consensus(reason=reason)

    def notify_locality(self, chunk: int) -> None:
        """Adaptive-controller proposal (run-length collapse): locality
        may only change uniformly, so route it to the coordinator, which
        drops it when the fleet searches no locality axis."""
        if self.coordinator is not None:
            self.coordinator.request_locality(chunk, host=self.host)

    # ---- act (coordinator-driven) ------------------------------------------
    def apply_params(self, nworker: int, nprefetch: int,
                     locality_chunk: Optional[int] = None, *,
                     locality_epoch: Optional[int] = None,
                     cache_budget_bytes: Optional[int] = None
                     ) -> LoaderParams:
        """Push tuned params into the live loader.  ``locality_chunk`` and
        ``cache_budget_bytes`` are only ever set by a fleet-uniform push,
        which also pins the common ``locality_epoch`` every host latches
        the new chunk (and cache plan) at.  A budget push resizes the
        host's live tier in place — warm entries survive the swap."""
        params = self.loader.params.replace(
            num_workers=nworker, prefetch_factor=nprefetch)
        if locality_chunk is not None:
            params = params.replace(locality_chunk=locality_chunk)
        if cache_budget_bytes is not None:
            params = params.replace(cache_budget_bytes=cache_budget_bytes)
        return self.loader.apply_params(params,
                                        locality_epoch=locality_epoch)

    def reshard(self, num_shards: int, shard: int, *,
                at_batch: Optional[int] = None,
                makeup: Optional[Sequence[np.ndarray]] = None) -> int:
        return self.loader.reshard(num_shards, shard, at_batch=at_batch,
                                   makeup=makeup)

    def add_makeup(self, makeup: Sequence[np.ndarray]) -> None:
        self.loader.add_makeup(makeup)

    def undelivered_makeup(self) -> List[np.ndarray]:
        """Makeup this host accepted but never CONSUMED — including
        batches its device prefetcher held at death (the stream's
        yield-side accounting alone would count those as delivered)."""
        stream = self._consume_stream
        if self.consumes_stream and stream is not None \
                and stream is self.loader._live_stream:
            return stream.undelivered_makeup(
                consumed_yields=self.steps - self._bind_steps)
        return self.loader.undelivered_makeup()

    def align_to(self, position: int) -> None:
        """Point a FRESH loader (no live stream yet) at an absolute
        global-batch position — how a joining host meets the fleet at the
        barrier."""
        sampler = self.loader.sampler
        from repro.data.sampler import SamplerState
        sampler.state = SamplerState.from_absolute(
            position, sampler.batches_per_epoch())
        self._base = position
        self.steps = 0
        self._consume_stream = None
        self._bind_steps = 0


# --------------------------------------------------------------------------
# the coordinator: decide
# --------------------------------------------------------------------------
class FleetCoordinator:
    """Aggregates host reports and drives fleet-wide tuning + resharding.

    Drive it with ``ingest``/``beat`` (or let registered agents do that
    through ``observe``) and call ``poll()`` from the control loop —
    every action taken is appended to ``events`` and returned.
    """

    def __init__(self, *, config: FleetConfig = FleetConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = config
        self.clock = clock
        self.registry = HeartbeatRegistry(
            timeout_s=config.heartbeat_timeout_s, clock=clock)
        self.straggler = StragglerDetector(
            window=config.straggler_window,
            threshold=config.straggler_threshold)
        self.agents: Dict[str, HostAgent] = {}
        self.reports: Dict[str, HostReport] = {}
        self.events: List[Dict[str, Any]] = []
        self.consensus_runs = 0
        self.reshards = 0
        self._last_consensus_step = -config.cooldown_steps
        self._backoff = 1
        self._forced_reason: Optional[str] = None

    # ---- membership --------------------------------------------------------
    def register(self, agent: HostAgent) -> HostAgent:
        agent.coordinator = self
        self.agents[agent.host] = agent
        self.registry.beat(agent.host)
        return agent

    @staticmethod
    def _negotiate_barrier(agents: Sequence[HostAgent], num_shards: int,
                           floor: int) -> int:
        """Issue the reshard to every agent at a common barrier, re-issuing
        at the max EFFECTIVE barrier until it is common.

        A live stream whose prefetcher raced past the proposed barrier
        clamps its boundary up and reports it; since a pending request
        pins the stream at its boundary, each re-issue round can only
        raise the barrier and the loop converges (normally in one pass).
        """
        barrier = max([a.stream_position() for a in agents] + [floor])
        while True:
            effective = max(a.reshard(num_shards, i, at_batch=barrier)
                            for i, a in enumerate(agents))
            if effective <= barrier:
                return barrier
            barrier = effective

    def join(self, agent: HostAgent) -> int:
        """Admit a new host mid-run: every existing host reshards to
        H+1 shards at a common barrier, the newcomer is aligned to that
        barrier and takes the last shard.  Returns the barrier."""
        incumbents = [self.agents[h] for h in sorted(self.agents)]
        new_count = len(incumbents) + 1
        barrier = self._negotiate_barrier(incumbents, new_count, 0)
        agent.align_to(barrier)
        if incumbents:
            # locality is runtime-mutable now: the joiner's construction-
            # time chunk can be stale, and a host slicing a different
            # epoch permutation than its peers silently loses/duplicates
            # samples.  Copy an incumbent's full (epoch -> chunk)
            # schedule — including any pending latch — before the stream
            # starts.
            src = incumbents[0].loader
            agent.loader.sampler.load_locality(
                src.sampler.locality_state())
            agent.loader.params = agent.loader.params.replace(
                locality_chunk=src.params.locality_chunk,
                cache_budget_bytes=src.params.cache_budget_bytes)
            # same staleness risk for the cache plan: the interleaved
            # epoch order depends on (chunk, hot_k), so the joiner must
            # slice the same permutation as its peers — copy the full
            # (epoch -> hot_k) schedule, then size the joiner's own
            # (empty) tier to the copied budget.  The sync is a schedule
            # no-op when the computed hot_k matches the copied plan.
            agent.loader.sampler.load_cache_plan(
                src.sampler.cache_state())
            agent.loader._sync_cache_plan()
        agent.loader.reshard(new_count, new_count - 1)
        self.register(agent)
        self.reshards += 1
        self.events.append({"kind": "join", "host": agent.host,
                            "barrier": barrier, "hosts": new_count})
        # the local batch shrank on every incumbent: re-tune for the new
        # topology at the next poll
        if self._forced_reason is None:
            self._forced_reason = "post-reshard"
        return barrier

    def leave(self, host: str) -> None:
        """Graceful departure: same reshard as a death, but the host's
        stream position needs no makeup beyond its own report."""
        self._reshard_around([host], reason="leave")

    # ---- observe ingestion -------------------------------------------------
    def beat(self, host: str) -> None:
        self.registry.beat(host)

    def ingest(self, report: HostReport) -> None:
        self.registry.beat(report.host)
        if report.batch_seconds:
            self.straggler.record(
                report.host,
                sum(report.batch_seconds) / len(report.batch_seconds))
        self.reports[report.host] = report

    def request_consensus(self, *, reason: str) -> None:
        """Out-of-band drift signal (serving batch-mix, operator): run a
        re-consensus at the next ``poll`` regardless of cooldown."""
        self._forced_reason = reason

    def request_locality(self, chunk: int, *, host: str = "?") -> None:
        """A host's adaptive locality controller observed a run-length
        collapse.  Locality can only change uniformly, so this requests a
        locality re-consensus — and is DROPPED when the fleet searches no
        locality axis (``FleetConfig.locality_chunks`` unset): a forced
        search that cannot touch the knob would just burn goodput on
        every repeated proposal."""
        if not self.cfg.locality_chunks:
            return
        self.request_consensus(
            reason=f"locality-run-len-collapse:{host}->{int(chunk)}")

    # ---- decide ------------------------------------------------------------
    @property
    def fleet_step(self) -> int:
        return max((r.steps for r in self.reports.values()), default=0)

    def fleet_stall_ratio(self) -> float:
        alive = set(self.registry.alive_hosts())
        ratios = [r.stall_ratio for h, r in self.reports.items()
                  if h in alive]
        return sum(ratios) / len(ratios) if ratios else 0.0

    def drifted(self) -> bool:
        return self.fleet_stall_ratio() > self.cfg.stall_fraction

    def poll(self) -> List[Dict[str, Any]]:
        """One decide step: handle deaths, then drift/straggler consensus.
        Returns the actions taken (also appended to ``events``)."""
        actions: List[Dict[str, Any]] = []
        dead = [h for h in self.registry.dead_hosts() if h in self.agents]
        if dead:
            # one reshard around ALL currently-dead hosts: handling them
            # one at a time would hand a dead "survivor" a shard (and a
            # makeup share) it can never deliver
            actions.append(self._reshard_around(dead, reason="dead"))
        reason = self._consensus_reason()
        if reason is not None:
            act = self._reconsensus(reason)
            if act is not None:
                actions.append(act)
        return actions

    def _consensus_reason(self) -> Optional[str]:
        if self._forced_reason is not None:
            reason, self._forced_reason = self._forced_reason, None
            return reason
        if self.fleet_step < self.cfg.warmup_steps:
            return None
        cooldown = self.cfg.cooldown_steps * self._backoff
        if self.fleet_step - self._last_consensus_step < cooldown:
            return None
        stragglers = self.straggler.stragglers()
        if stragglers:
            return f"straggler-divergence:{','.join(stragglers)}"
        if self.drifted():
            return "goodput-drift"
        return None

    # ---- act: uniform re-consensus -----------------------------------------
    def _search_config(self) -> DPTConfig:
        cfg = DPTConfig(num_cpu_cores=self.cfg.num_cpu_cores,
                        num_devices=self.cfg.num_devices,
                        max_prefetch=self.cfg.max_prefetch)
        return dataclasses.replace(cfg, num_batches=adaptive_budget(
            cfg, self.cfg.retune_budget_batches))

    def _reconsensus(self, reason: str) -> Optional[Dict[str, Any]]:
        """Uniform re-consensus over every live host's evaluator, pushed
        to the whole fleet through apply_params."""
        hosts = sorted(h for h in self.agents
                       if h in set(self.registry.alive_hosts()))
        if not hosts:
            return None
        agents = [self.agents[h] for h in hosts]
        originals = [a.loader.params for a in agents]
        tuner = MultiHostDPT([a.evaluator for a in agents],
                             self._search_config())
        self._last_consensus_step = self.fleet_step
        try:
            fleet = tuner.run_uniform()
        except MemoryOverflow:
            self._backoff = min(self.cfg.max_backoff, self._backoff * 2)
            return None
        finally:
            # trial cells mutate loader params via with_params; a live
            # stream must never rebuild on trial params
            for a, orig in zip(agents, originals):
                a.loader.with_params(orig)
        self.consensus_runs += 1
        won = self._is_fleet_win(fleet, agents)
        # the online locality axis: sweep chunk candidates at the cell the
        # fleet will actually run (the winner if it won, else the current
        # majority cell), scored by the fleet max
        cell = fleet.uniform_params if won \
            else self._majority_cell(agents)
        chunk_win = self._locality_consensus(agents, cell)
        budget_win = self._cache_consensus(agents, cell)
        applied = won or chunk_win is not None or budget_win is not None
        self._backoff = 1 if applied else min(self.cfg.max_backoff,
                                              self._backoff * 2)
        event = {"kind": "consensus", "reason": reason,
                 "params": fleet.uniform_params,
                 "fleet_time": fleet.fleet_time, "hosts": hosts,
                 # "applied" = anything changed; "cell_applied" = the
                 # uniform (workers, prefetch) winner itself rolled out
                 # (False for a locality-only apply: hosts keep their
                 # current cells and only the chunk changes)
                 "cell_applied": won,
                 "locality_chunk": chunk_win,
                 "cache_budget_bytes": budget_win,
                 "applied": applied}
        self.events.append(event)
        if applied:
            # one common latch epoch: every host adopts the new chunk AND
            # the new cache plan for the SAME epoch even when producers
            # straddle a boundary (the interleaved order depends on both)
            latch = max(a.loader.locality_latch_epoch() for a in agents) \
                if (chunk_win is not None or budget_win is not None) \
                else None
            for a in agents:
                nw, npf = fleet.uniform_params if won else (
                    a.loader.params.num_workers,
                    a.loader.params.prefetch_factor)
                a.apply_params(nw, npf, locality_chunk=chunk_win,
                               locality_epoch=latch,
                               cache_budget_bytes=budget_win)
        return event

    @staticmethod
    def _current_cells(agents: Sequence[HostAgent]
                       ) -> Dict[Tuple[int, int], int]:
        counts: Dict[Tuple[int, int], int] = {}
        for a in agents:
            p = a.loader.params
            key = (p.num_workers, p.prefetch_factor)
            counts[key] = counts.get(key, 0) + 1
        return counts

    @classmethod
    def _majority_cell(cls, agents: Sequence[HostAgent]) -> Tuple[int, int]:
        counts = cls._current_cells(agents)
        return max(counts, key=counts.get)

    def _locality_consensus(self, agents: Sequence[HostAgent],
                            cell: Tuple[int, int]) -> Optional[int]:
        """Uniform locality decision: per-host chunk sweeps at ``cell``,
        aggregated by the fleet max; the winner must beat the current
        chunk's own fleet time by ``min_improvement`` and be feasible on
        every host.  Returns the winning chunk or None (keep)."""
        if not self.cfg.locality_chunks:
            return None
        from repro.tuning.locality import sweep_locality
        cfg = self._search_config()
        cur = agents[0].loader.params.locality_chunk
        originals = [a.loader.params for a in agents]
        try:
            per_host = [sweep_locality(
                a.evaluator, nworker=cell[0], nprefetch=cell[1],
                chunks=self.cfg.locality_chunks, current_chunk=cur,
                num_batches=cfg.num_batches) for a in agents]
        finally:
            for a, orig in zip(agents, originals):
                a.loader.with_params(orig)
        fleet_time: Dict[int, float] = {}
        for trials in per_host:
            for chunk, t in trials.items():
                fleet_time[chunk] = max(fleet_time.get(chunk, 0.0),
                                        t.seconds)
        feasible = {c: s for c, s in fleet_time.items()
                    if math.isfinite(s)}
        if not feasible:
            return None
        best = min(feasible, key=feasible.get)
        if best == cur:
            return None
        if cur not in feasible:
            return best                   # current chunk infeasible somewhere
        if feasible[best] <= (1.0 - self.cfg.min_improvement) * feasible[cur]:
            return best
        return None

    def _cache_consensus(self, agents: Sequence[HostAgent],
                         cell: Tuple[int, int]) -> Optional[int]:
        """Uniform cache-budget decision (DESIGN.md §7): per-host budget
        sweeps at ``cell`` measured at a WARM epoch (a cross-epoch cache
        prices at 0 cold), aggregated by the fleet max; the winner must
        beat the current budget's own fleet time by ``min_improvement``
        and be feasible on every host.  Returns the winning budget or
        None (keep)."""
        if not self.cfg.cache_budgets:
            return None
        from repro.tuning.locality import sweep_cache
        cfg = self._search_config()
        cur = agents[0].loader.params.cache_budget_bytes
        originals = [a.loader.params for a in agents]
        try:
            per_host = [sweep_cache(
                a.evaluator, nworker=cell[0], nprefetch=cell[1],
                budgets=self.cfg.cache_budgets, current_budget=cur,
                num_batches=cfg.num_batches,
                epoch=max(1, cfg.epoch)) for a in agents]
        finally:
            for a, orig in zip(agents, originals):
                a.loader.with_params(orig)
        fleet_time: Dict[int, float] = {}
        for trials in per_host:
            for budget, t in trials.items():
                fleet_time[budget] = max(fleet_time.get(budget, 0.0),
                                         t.seconds)
        feasible = {b: s for b, s in fleet_time.items()
                    if math.isfinite(s)}
        if not feasible:
            return None
        best = min(feasible, key=feasible.get)
        if best == cur:
            return None
        if cur not in feasible:
            return best                  # current budget infeasible somewhere
        if feasible[best] <= (1.0 - self.cfg.min_improvement) * feasible[cur]:
            return best
        return None

    def _is_fleet_win(self, fleet, agents: Sequence[HostAgent]) -> bool:
        """Anti-churn at fleet scope: the uniform winner must differ from
        the current (majority) config and beat that config's own measured
        fleet time by ``min_improvement``."""
        current = self._current_cells(agents)
        cur_cell = max(current, key=current.get)
        if fleet.uniform_params == cur_cell and len(current) == 1:
            return False
        cur_times = []
        for r in fleet.per_host:
            t = next((t for t in r.trials
                      if (t.nworker, t.nprefetch) == cur_cell
                      and math.isfinite(t.seconds)), None)
            if t is None:
                return True          # current cell infeasible somewhere
            cur_times.append(t.seconds)
        cur_fleet = max(cur_times)
        return fleet.fleet_time \
            <= (1.0 - self.cfg.min_improvement) * cur_fleet

    # ---- act: elastic reshard ----------------------------------------------
    def _reshard_around(self, hosts: Sequence[str], *,
                        reason: str) -> Dict[str, Any]:
        """One or more hosts left the fleet (a rack failure is one event,
        not a cascade): remap every survivor at one common barrier and
        redistribute every departed host's undelivered slices."""
        departed = [self.agents.pop(h) for h in hosts]
        for h in hosts:
            self.registry.remove(h)
            self.straggler.forget(h)
            self.reports.pop(h, None)
        # survivors keep their relative order; shard indices compact
        survivors = sorted(self.agents.values(),
                           key=lambda a: a.loader.sampler.host_index)
        new_count = len(survivors)
        old_count = new_count + len(departed)
        consumed = {d.host: d.consumed_position() for d in departed}
        event: Dict[str, Any] = {"kind": "reshard", "reason": reason,
                                 "lost": list(hosts), "host": hosts[0],
                                 "dead_consumed": consumed,
                                 "hosts": new_count}
        if not survivors:
            event.update(barrier=None, makeup_batches=0, plan=None)
            self.events.append(event)
            return event
        barrier = self._negotiate_barrier(
            survivors, new_count, max(consumed.values(), default=0))
        plan = plan_remesh(
            alive_hosts=new_count,
            devices_per_host=self.cfg.devices_per_host,
            model_axis=self.cfg.model_axis,
            old_hosts=old_count,
            old_global_batch=departed[0].loader.sampler.global_batch,
            restore_step=barrier)
        # makeup: every departed host's undelivered slices up to the
        # settled barrier, PLUS any makeup chunks a previous reshard dealt
        # to it that it never delivered (makeup parked on a corpse is
        # otherwise lost), re-chunked to the NEW local batch size (so the
        # chunks share the regular batch shape and can use the re-specced
        # arena; at most one ragged tail chunk bypasses it) and dealt
        # round-robin over survivors
        missing: List[np.ndarray] = []
        makeup_batches = 0
        for d in departed:
            sampler = d.loader.sampler           # OLD shard map, frozen
            bpe = sampler.batches_per_epoch()
            for b in range(consumed[d.host], barrier):
                missing.append(sampler.local_indices(b // bpe, b % bpe))
                makeup_batches += 1
            inherited = d.undelivered_makeup()
            missing.extend(inherited)
            makeup_batches += len(inherited)
        if missing:
            flat = np.concatenate(missing)
            new_local = survivors[0].loader.sampler.global_batch // new_count
            chunks = [flat[i:i + new_local]
                      for i in range(0, len(flat), new_local)]
            shares: List[List[np.ndarray]] = [[] for _ in survivors]
            for i, chunk in enumerate(chunks):
                shares[i % new_count].append(chunk)
            for a, share in zip(survivors, shares):
                if share:
                    a.add_makeup(share)
        self.reshards += 1
        # the per-host optimum moved with the local batch size: follow the
        # reshard with a re-consensus for the new topology at next poll
        if self._forced_reason is None:
            self._forced_reason = "post-reshard"
        event.update(barrier=barrier, makeup_batches=makeup_batches,
                     plan=plan)
        self.events.append(event)
        return event
