"""The built-in tuning strategies, ported onto the TuningStrategy protocol.

* ``grid``                 — the paper's Algorithm 1, kept faithful (worker
  rungs of G up to N, prefetch 1..P, overflow breaks the inner loop), with
  the final worker rung clamped to N when N is not divisible by G.
* ``successive_halving``   — Hyperband-style rung schedule: measure every
  cell with a tiny batch budget, keep the best 1/eta, grow the budget.
* ``hillclimb``            — coordinate descent (±G workers, ±1 prefetch)
  from a caller-supplied start cell.
* ``warmstart_hillclimb``  — seed the hillclimb with the simulator cost
  model's analytic optimum (zero measurements), then refine for real.
* ``goodput``              — smallest (nWorker, nPrefetch) whose transfer
  time merely outpaces the model step; frees cores where the model, not
  the loader, is the bottleneck.

All of these used to live as separately-shaped functions in ``core/dpt.py``
and ``core/search.py``; those modules now delegate here.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.dpt import DPTConfig, DPTResult, default_params
from repro.core.monitor import MemoryOverflow
from repro.core.simulator import LoaderSimulator, MachineProfile
from repro.data.storage import StorageProfile
from repro.tuning.base import TrialRecorder, register_strategy, worker_rungs


@register_strategy("grid")
class GridSearch:
    """Paper Algorithm 1.

    Line mapping: the outer ``for i in worker_rungs`` is lines 4-5 (with
    the overshoot fix: the last rung is clamped to N), the inner prefetch
    sweep is lines 6-7, overflow-breaks are lines 9-10, the running argmin
    is lines 14-17.  The optional default-parameter reference run
    reproduces the paper's comparison against PyTorch defaults and is not
    recorded as a sweep trial.

    Beyond paper: when ``config.locality_chunks`` is set, the same sweep
    repeats per candidate sampler chunk size — a third, outermost axis
    (DESIGN.md §5).  ``config.cache_budgets`` adds the fourth axis the
    same way (DESIGN.md §7), ``config.slow_lanes`` a fifth (DESIGN.md §9)
    and ``config.geometries`` (candidate global batches, DESIGN.md §11) a
    sixth, outermost of all.  Left unset (the default), the loop is
    exactly Algorithm 1 and the evaluator never sees a locality, cache,
    slow-lane or geometry kwarg.
    """

    def tune(self, rec: TrialRecorder, *,
             measure_default: bool = True) -> DPTResult:
        cfg = rec.config
        N, G = cfg.resolve()
        chunks = cfg.locality_chunks if cfg.locality_chunks else (None,)
        budgets = cfg.cache_budgets if cfg.cache_budgets else (None,)
        lanes = cfg.slow_lanes if cfg.slow_lanes else (None,)
        geoms = cfg.geometries if cfg.geometries else (None,)
        n_worker, n_prefetch = 0, 0
        n_chunk, n_budget, n_lane, n_geom = 0, 0, 0, 0
        optimal_time = math.inf
        for g in geoms:                            # beyond-paper axis 6
            for s in lanes:                        # beyond-paper axis 5
                for b in budgets:                  # beyond-paper axis 4
                    for c in chunks:               # beyond-paper axis 3
                        for i in worker_rungs(N, G):       # lines 4-5
                            j = cfg.min_prefetch           # line 6
                            while j <= cfg.max_prefetch:   # line 7
                                t = rec.seconds(i, j,      # lines 8, 12
                                                locality_chunk=c,
                                                cache_budget_bytes=b,
                                                slow_lane_workers=s,
                                                global_batch=g)
                                if not math.isfinite(t):   # lines 9-10
                                    break
                                if t < optimal_time:       # lines 14-17
                                    optimal_time = t
                                    n_worker, n_prefetch = i, j
                                    n_chunk = c or 0
                                    n_budget = b or 0
                                    n_lane = s or 0
                                    n_geom = g or 0
                                j += 1                     # line 19
        default_time = None
        if measure_default:
            dw, dp = default_params(N)
            default_time = rec.seconds(dw, dp, record=False)
        return rec.result(n_worker, n_prefetch, optimal_time,
                          default_time=default_time,
                          locality_chunk=n_chunk,
                          cache_budget_bytes=n_budget,
                          slow_lane_workers=n_lane,
                          global_batch=n_geom)


@register_strategy("successive_halving")
class SuccessiveHalving:
    """Measure all cells cheaply, keep the best 1/eta, multiply the budget."""

    def tune(self, rec: TrialRecorder, *, eta: int = 3,
             min_batches: int = 4) -> DPTResult:
        cfg = rec.config
        N, G = cfg.resolve()
        cells: List[Tuple[int, int]] = [
            (i, j) for i in worker_rungs(N, G)
            for j in range(cfg.min_prefetch, cfg.max_prefetch + 1)]
        budget = min_batches
        while True:
            scores = {c: rec.seconds(c[0], c[1], num_batches=budget)
                      for c in cells}
            alive = [c for c in cells if math.isfinite(scores[c])]
            if not alive:
                raise MemoryOverflow("all cells overflow")
            alive.sort(key=lambda c: scores[c])
            if len(alive) <= 2 or budget >= cfg.num_batches:
                best = alive[0]
                return rec.result(best[0], best[1], scores[best])
            cells = alive[:max(2, len(alive) // eta)]
            budget = min(budget * eta, cfg.num_batches)


@register_strategy("hillclimb")
class HillClimb:
    """Coordinate descent on the (worker, prefetch) grid from ``start``."""

    def tune(self, rec: TrialRecorder, *, start: Tuple[int, int],
             max_steps: int = 24) -> DPTResult:
        cfg = rec.config
        N, G = cfg.resolve()
        lo_j, hi_j = cfg.min_prefetch, cfg.max_prefetch

        def clamp(i, j):
            # snap onto Algorithm 1's rung set {G, 2G, ..., N}: N itself is
            # a rung even when not a multiple of G (the clamped final rung)
            if i >= N:
                i = N
            else:
                i = max(G, (i // G) * G if i % G else i)
            return i, max(lo_j, min(hi_j, j))

        seen: Dict[Tuple[int, int], float] = {}

        def score(cell):
            if cell not in seen:
                seen[cell] = rec.seconds(cell[0], cell[1])
            return seen[cell]

        cur = clamp(*start)
        best_t = score(cur)
        if not math.isfinite(best_t):
            # Infeasible start (e.g. the host lost RAM mid-run and the
            # stale optimum now overflows): walk down the worker axis —
            # the dominant footprint term — then down prefetch, until a
            # feasible cell is found, and refine from there.
            i, j = cur
            escape = [clamp(k, j) for k in range(i - G, 0, -G)]
            escape += [clamp(G, q) for q in range(j - 1, lo_j - 1, -1)]
            for cell in escape:
                if math.isfinite(score(cell)):
                    cur, best_t = cell, score(cell)
                    break
        for _ in range(max_steps):
            i, j = cur
            neighbors = [clamp(i + G, j), clamp(i - G, j),
                         clamp(i, j + 1), clamp(i, j - 1)]
            cand = min(neighbors, key=score)
            if score(cand) + 1e-12 < best_t:
                cur, best_t = cand, score(cand)
            else:
                break
        if not math.isfinite(best_t):
            raise MemoryOverflow("hillclimb found no feasible cell")
        return rec.result(cur[0], cur[1], best_t)


@dataclasses.dataclass
class CostModelPrediction:
    nworker: int
    nprefetch: int
    predicted_seconds: float


def cost_model_warmstart(storage: StorageProfile, machine: MachineProfile,
                         *, batch_size: int, config: DPTConfig = DPTConfig(),
                         ) -> CostModelPrediction:
    """Zero-measurement analytic optimum from the simulator's own cost model
    (the napkin math, mechanized).  Used to seed the hillclimb on a new
    machine/dataset pair before any wall-clock run."""
    sim = LoaderSimulator(storage, machine)
    N, G = config.resolve()
    best = None
    for i in worker_rungs(N, G):
        for j in range(config.min_prefetch, config.max_prefetch + 1):
            try:
                r = sim.simulate(batch_size=batch_size, num_batches=32,
                                 nworker=i, nprefetch=j, epoch=config.epoch)
            except MemoryOverflow:
                break
            if best is None or r.seconds < best[2]:
                best = (i, j, r.seconds)
    if best is None:
        raise MemoryOverflow("cost model: every cell overflows")
    return CostModelPrediction(*best)


@register_strategy("warmstart_hillclimb")
class WarmstartHillClimb:
    """Cost-model warmstart (free) + measured hillclimb (cheap)."""

    def tune(self, rec: TrialRecorder, *, storage: StorageProfile,
             machine: MachineProfile, batch_size: int,
             max_steps: int = 24) -> DPTResult:
        pred = cost_model_warmstart(storage, machine, batch_size=batch_size,
                                    config=rec.config)
        return HillClimb().tune(rec, start=(pred.nworker, pred.nprefetch),
                                max_steps=max_steps)


@register_strategy("goodput")
class GoodputTune:
    """Minimal-resource tuning: the loader only needs to outpace the model.

    Finds the smallest (nworker, nprefetch) whose transfer time for
    ``num_batches`` is <= step_time * (1 - margin) * num_batches; falls
    back to the global optimum if no cell meets the target.
    """

    def tune(self, rec: TrialRecorder, *, step_time_s: float,
             num_batches: int, margin: float = 0.1) -> DPTResult:
        cfg = rec.config
        N, G = cfg.resolve()
        target = step_time_s * (1.0 - margin) * num_batches
        best_any: Optional[Tuple[int, int, float]] = None
        for i in worker_rungs(N, G):
            for j in range(cfg.min_prefetch, cfg.max_prefetch + 1):
                t = rec.seconds(i, j, num_batches=num_batches)
                if not math.isfinite(t):
                    break
                if best_any is None or t < best_any[2]:
                    best_any = (i, j, t)
                if t <= target:
                    return rec.result(i, j, t)
        if best_any is None:
            raise MemoryOverflow("goodput: every cell overflows")
        return rec.result(*best_any)
