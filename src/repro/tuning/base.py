"""Unified tuning layer: one protocol, one registry, one front door.

Before this layer, the repo had four tuning entry points with four
signatures (``DPT.run``, ``search.successive_halving``,
``search.tuned_with_warmstart``, ``search.goodput_tune``), each carrying
its own Trial bookkeeping and MemoryOverflow handling.  Now every tuner is
a :class:`TuningStrategy` registered by name, measured through a shared
:class:`TrialRecorder`, and reachable through::

    from repro.tuning import tune
    result = tune(evaluator=ev, strategy="grid", config=DPTConfig(...))

The legacy entry points still exist and delegate here, so nothing that
imported them moves — but new call sites (OnlineTuner, the trainer, the
benchmarks) only need the one function.

How Algorithm 1 maps on:  the paper's grid sweep is the ``"grid"``
strategy (see ``strategies.GridSearch`` — the loop is a line-for-line port
of Algorithm 1 with the final worker rung clamped to N); the evaluator it
measures cells with is unchanged (``core/evaluators.py``); the
``DPTConfig``/``DPTResult``/``Trial`` dataclasses stay in ``core/dpt.py``
because they predate the layer and everything imports them from there.
"""
from __future__ import annotations

import math
from typing import (Dict, List, Optional, Protocol, Sequence, Type, Union,
                    runtime_checkable)

from repro.core.dpt import DPTConfig, DPTResult, Evaluator, Trial
from repro.core.monitor import MemoryOverflow


class TrialRecorder:
    """Shared measurement bookkeeping for every strategy.

    Wraps an evaluator and records one :class:`Trial` per real measurement,
    normalizing the two ways a cell can overflow (the evaluator raising
    ``MemoryOverflow``, or returning ``TransferStats(overflowed=True)``)
    into a single ``math.inf`` score — the semantics Algorithm 1's
    lines 9-10 act on.
    """

    def __init__(self, evaluator: Evaluator, config: DPTConfig):
        self.evaluator = evaluator
        self.config = config
        self.trials: List[Trial] = []

    def seconds(self, nworker: int, nprefetch: int, *,
                num_batches: Optional[int] = None,
                record: bool = True,
                locality_chunk: Optional[int] = None,
                cache_budget_bytes: Optional[int] = None,
                slow_lane_workers: Optional[int] = None,
                global_batch: Optional[int] = None) -> float:
        """Measure one cell; ``math.inf`` on overflow.

        ``record=False`` measures without logging a Trial (used for the
        paper's default-parameter reference run, which is not part of the
        sweep).  ``locality_chunk`` is the beyond-paper third axis,
        ``cache_budget_bytes`` the fourth, ``slow_lane_workers`` the
        fifth and ``global_batch`` (elastic geometry) the sixth; each is
        forwarded to the evaluator ONLY when set, so lower-dimensional
        searches keep working against evaluators that never heard of
        them.
        """
        nb = self.config.num_batches if num_batches is None else num_batches
        kw = {}
        if locality_chunk is not None:
            kw["locality_chunk"] = locality_chunk
        if cache_budget_bytes is not None:
            kw["cache_budget_bytes"] = cache_budget_bytes
        if slow_lane_workers is not None:
            kw["slow_lane_workers"] = slow_lane_workers
        if global_batch is not None:
            kw["global_batch"] = global_batch
        chunk = locality_chunk or 0
        budget = cache_budget_bytes or 0
        lanes = slow_lane_workers or 0
        gb = global_batch or 0
        try:
            stats = self.evaluator(nworker, nprefetch, num_batches=nb,
                                   epoch=self.config.epoch, **kw)
        except MemoryOverflow:
            if record:
                self.trials.append(Trial(nworker, nprefetch, math.inf,
                                         overflowed=True,
                                         locality_chunk=chunk,
                                         cache_budget_bytes=budget,
                                         slow_lane_workers=lanes,
                                         global_batch=gb))
            return math.inf
        if stats.overflowed:
            if record:
                self.trials.append(Trial(nworker, nprefetch, math.inf,
                                         overflowed=True,
                                         locality_chunk=chunk,
                                         cache_budget_bytes=budget,
                                         slow_lane_workers=lanes,
                                         global_batch=gb))
            return math.inf
        if record:
            self.trials.append(Trial(
                nworker, nprefetch, stats.seconds,
                peak_bytes=stats.peak_loader_bytes,
                batch_seconds=getattr(stats, "batch_seconds", None),
                locality_chunk=chunk,
                cache_budget_bytes=budget,
                slow_lane_workers=lanes,
                global_batch=gb))
        return stats.seconds

    def result(self, nworker: int, nprefetch: int, optimal_time: float,
               *, default_time: Optional[float] = None,
               locality_chunk: int = 0,
               cache_budget_bytes: int = 0,
               slow_lane_workers: int = 0,
               global_batch: int = 0) -> DPTResult:
        return DPTResult(nworker, nprefetch, optimal_time, self.trials,
                         default_time=default_time,
                         locality_chunk=locality_chunk,
                         cache_budget_bytes=cache_budget_bytes,
                         slow_lane_workers=slow_lane_workers,
                         global_batch=global_batch)


def worker_rungs(num_cpu_cores: int, num_devices: int) -> List[int]:
    """Algorithm 1's worker sweep: G, 2G, ... clamped to end exactly at N.

    The paper's ``while i < N: i += G`` overshoots when N is not divisible
    by G (it would measure more workers than the host has cores); the final
    rung is clamped to N instead.
    """
    rungs: List[int] = []
    i = 0
    while i < num_cpu_cores:
        i = min(i + num_devices, num_cpu_cores)
        rungs.append(i)
    return rungs


def adaptive_budget(config: DPTConfig,
                    explicit: Optional[int] = None) -> int:
    """Measurement budget per trial cell.

    With budget <= nWorker every config finishes in one parallel wave and
    all cells measure identically (pipeline fill, not steady-state rate),
    so the budget must comfortably exceed the largest worker count in the
    search space.  ``explicit`` (a user-set budget) wins; otherwise the
    budget is 3x the deepest worker rung, floored at 8.
    """
    if explicit is not None:
        return explicit
    n, g = config.resolve()
    rungs = worker_rungs(n, g)
    return max(8, 3 * (rungs[-1] if rungs else 1))


# one-sided Student-t critical values at alpha=0.05, indexed by df (1-based)
# through df=40, then stepped toward the normal tail — monotone, so the
# test never gets abruptly laxer as the sample count crosses a boundary
_T05 = [6.314, 2.920, 2.353, 2.132, 2.015, 1.943, 1.895, 1.860, 1.833,
        1.812, 1.796, 1.782, 1.771, 1.761, 1.753, 1.746, 1.740, 1.734,
        1.729, 1.725, 1.721, 1.717, 1.714, 1.711, 1.708, 1.706, 1.703,
        1.701, 1.699, 1.697, 1.696, 1.694, 1.692, 1.691, 1.690, 1.688,
        1.687, 1.686, 1.685, 1.684]


def t_critical(df: float) -> float:
    if df < 1:
        return _T05[0]
    if df < len(_T05):
        return _T05[int(df) - 1]
    # bracket lower-bound values: conservative and monotone past the table
    if df < 60:
        return 1.684
    if df < 120:
        return 1.671
    return 1.658


def steady_samples(samples: Optional[Sequence[float]]) -> List[float]:
    """Drop a measurement's pipeline-fill prefix (pool spin-up + first
    reads): the adaptive budget reserves ~1/3 of the batches for fill,
    and leaving it in inflates variance on both sides of a Welch test,
    gutting its power.  Shared by every win test that feeds welch_wins."""
    if not samples:
        return []
    return list(samples[len(samples) // 3:])


def welch_wins(current: Sequence[float], candidate: Sequence[float]) -> bool:
    """Variance-aware win test: is the candidate's mean per-batch time
    significantly lower than the current config's?

    Welch's unequal-variance t-test, one-sided at alpha=0.05 with the
    Welch-Satterthwaite degrees of freedom.  Replaces a fixed relative
    ``min_improvement`` threshold: a noisy host needs a bigger gap to call
    a winner, a quiet host can act on a smaller one.
    """
    na, nb = len(current), len(candidate)
    if na < 2 or nb < 2:
        return False
    ma = sum(current) / na
    mb = sum(candidate) / nb
    va = sum((x - ma) ** 2 for x in current) / (na - 1)
    vb = sum((x - mb) ** 2 for x in candidate) / (nb - 1)
    sa, sb = va / na, vb / nb
    if sa + sb <= 0.0:
        return mb < ma
    t = (ma - mb) / math.sqrt(sa + sb)
    df = (sa + sb) ** 2 / (sa ** 2 / (na - 1) + sb ** 2 / (nb - 1))
    return t >= t_critical(df)


@runtime_checkable
class TuningStrategy(Protocol):
    """A search policy over the (nWorker, nPrefetch) plane.

    Strategies are stateless: all measurement state lives in the
    TrialRecorder they are handed, so one strategy instance can serve many
    searches and strategies can be chained on a shared recorder (the
    warmstart+hillclimb combo does exactly that).
    """

    name: str

    def tune(self, recorder: TrialRecorder, **kwargs) -> DPTResult:
        ...


_REGISTRY: Dict[str, Type] = {}


def register_strategy(name: str):
    """Class decorator: ``@register_strategy("grid")``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_strategy(name: str) -> TuningStrategy:
    _ensure_builtins()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown tuning strategy {name!r}; "
            f"available: {available_strategies()}")
    return _REGISTRY[name]()


def available_strategies() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


def _ensure_builtins() -> None:
    # strategies.py registers on import; lazy so base has no import cycle
    from repro.tuning import strategies  # noqa: F401


def tune(*, evaluator: Evaluator,
         strategy: Union[str, TuningStrategy] = "grid",
         config: DPTConfig = DPTConfig(), **kwargs) -> DPTResult:
    """The single tuning front door.

    ``strategy`` is a registry name (``"grid"``, ``"successive_halving"``,
    ``"hillclimb"``, ``"warmstart_hillclimb"``, ``"goodput"``) or a
    TuningStrategy instance; strategy-specific knobs (``start=``,
    ``step_time_s=``, ...) pass through ``**kwargs``.  Every strategy
    honours the same MemoryOverflow semantics and returns a ``DPTResult``
    whose ``trials`` list the real measurements performed.
    """
    strat = get_strategy(strategy) if isinstance(strategy, str) else strategy
    recorder = TrialRecorder(evaluator, config)
    return strat.tune(recorder, **kwargs)
