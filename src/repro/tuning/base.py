"""Unified tuning layer: one protocol, one registry, one front door.

Before this layer, the repo had four tuning entry points with four
signatures (``DPT.run``, ``search.successive_halving``,
``search.tuned_with_warmstart``, ``search.goodput_tune``), each carrying
its own Trial bookkeeping and MemoryOverflow handling.  Now every tuner is
a :class:`TuningStrategy` registered by name, measured through a shared
:class:`TrialRecorder`, and reachable through::

    from repro.tuning import tune
    result = tune(evaluator=ev, strategy="grid", config=DPTConfig(...))

The legacy entry points still exist and delegate here, so nothing that
imported them moves — but new call sites (OnlineTuner, the trainer, the
benchmarks) only need the one function.

How Algorithm 1 maps on:  the paper's grid sweep is the ``"grid"``
strategy (see ``strategies.GridSearch`` — the loop is a line-for-line port
of Algorithm 1 with the final worker rung clamped to N); the evaluator it
measures cells with is unchanged (``core/evaluators.py``); the
``DPTConfig``/``DPTResult``/``Trial`` dataclasses stay in ``core/dpt.py``
because they predate the layer and everything imports them from there.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Protocol, Type, Union, runtime_checkable

from repro.core.dpt import DPTConfig, DPTResult, Evaluator, Trial
from repro.core.monitor import MemoryOverflow


class TrialRecorder:
    """Shared measurement bookkeeping for every strategy.

    Wraps an evaluator and records one :class:`Trial` per real measurement,
    normalizing the two ways a cell can overflow (the evaluator raising
    ``MemoryOverflow``, or returning ``TransferStats(overflowed=True)``)
    into a single ``math.inf`` score — the semantics Algorithm 1's
    lines 9-10 act on.
    """

    def __init__(self, evaluator: Evaluator, config: DPTConfig):
        self.evaluator = evaluator
        self.config = config
        self.trials: List[Trial] = []

    def seconds(self, nworker: int, nprefetch: int, *,
                num_batches: Optional[int] = None,
                record: bool = True) -> float:
        """Measure one cell; ``math.inf`` on overflow.

        ``record=False`` measures without logging a Trial (used for the
        paper's default-parameter reference run, which is not part of the
        sweep).
        """
        nb = self.config.num_batches if num_batches is None else num_batches
        try:
            stats = self.evaluator(nworker, nprefetch, num_batches=nb,
                                   epoch=self.config.epoch)
        except MemoryOverflow:
            if record:
                self.trials.append(Trial(nworker, nprefetch, math.inf,
                                         overflowed=True))
            return math.inf
        if stats.overflowed:
            if record:
                self.trials.append(Trial(nworker, nprefetch, math.inf,
                                         overflowed=True))
            return math.inf
        if record:
            self.trials.append(Trial(nworker, nprefetch, stats.seconds,
                                     peak_bytes=stats.peak_loader_bytes))
        return stats.seconds

    def result(self, nworker: int, nprefetch: int, optimal_time: float,
               *, default_time: Optional[float] = None) -> DPTResult:
        return DPTResult(nworker, nprefetch, optimal_time, self.trials,
                         default_time=default_time)


def worker_rungs(num_cpu_cores: int, num_devices: int) -> List[int]:
    """Algorithm 1's worker sweep: G, 2G, ... clamped to end exactly at N.

    The paper's ``while i < N: i += G`` overshoots when N is not divisible
    by G (it would measure more workers than the host has cores); the final
    rung is clamped to N instead.
    """
    rungs: List[int] = []
    i = 0
    while i < num_cpu_cores:
        i = min(i + num_devices, num_cpu_cores)
        rungs.append(i)
    return rungs


@runtime_checkable
class TuningStrategy(Protocol):
    """A search policy over the (nWorker, nPrefetch) plane.

    Strategies are stateless: all measurement state lives in the
    TrialRecorder they are handed, so one strategy instance can serve many
    searches and strategies can be chained on a shared recorder (the
    warmstart+hillclimb combo does exactly that).
    """

    name: str

    def tune(self, recorder: TrialRecorder, **kwargs) -> DPTResult:
        ...


_REGISTRY: Dict[str, Type] = {}


def register_strategy(name: str):
    """Class decorator: ``@register_strategy("grid")``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_strategy(name: str) -> TuningStrategy:
    _ensure_builtins()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown tuning strategy {name!r}; "
            f"available: {available_strategies()}")
    return _REGISTRY[name]()


def available_strategies() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


def _ensure_builtins() -> None:
    # strategies.py registers on import; lazy so base has no import cycle
    from repro.tuning import strategies  # noqa: F401


def tune(*, evaluator: Evaluator,
         strategy: Union[str, TuningStrategy] = "grid",
         config: DPTConfig = DPTConfig(), **kwargs) -> DPTResult:
    """The single tuning front door.

    ``strategy`` is a registry name (``"grid"``, ``"successive_halving"``,
    ``"hillclimb"``, ``"warmstart_hillclimb"``, ``"goodput"``) or a
    TuningStrategy instance; strategy-specific knobs (``start=``,
    ``step_time_s=``, ...) pass through ``**kwargs``.  Every strategy
    honours the same MemoryOverflow semantics and returns a ``DPTResult``
    whose ``trials`` list the real measurements performed.
    """
    strat = get_strategy(strategy) if isinstance(strategy, str) else strategy
    recorder = TrialRecorder(evaluator, config)
    return strat.tune(recorder, **kwargs)
