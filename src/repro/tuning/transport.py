"""Message transport for the fleet control plane (DESIGN.md §8).

PR 3's HostAgent <-> FleetCoordinator protocol was direct in-process
method calls: ``observe()`` invoked ``coordinator.ingest(report)`` on the
same stack, and every coordinator command reached straight into the
agent's loader.  That shape cannot survive a real network — and a fleet
control plane that is only correct when messages always arrive and the
coordinator never dies is only correct in a simulator.

This module is the wire between them:

* every message is a **plain dict** (JSON-serializable after
  :func:`to_wire`) — ``HostReport`` and every coordinator->agent command
  (``apply_params``, ``reshard``, locality/cache pushes, barrier
  negotiation) crosses as data, never as an object reference, so a gRPC
  or etcd-watch backend can drop in behind :class:`LocalTransport`
  without touching ``FleetCoordinator.ingest``;
* :class:`FaultyTransport` injects seeded drop / delay / duplicate /
  reply-drop / partition faults, making "the network ate it" a
  first-class, deterministic test input;
* :class:`AgentLink` is the host's survival kit: bounded send queue,
  exponential backoff with jitter, report delta-encoding against the
  last acked base (heartbeat traffic stays O(hosts), not O(hosts x
  knobs)), replay-on-reconnect, and **fencing** — commands carry the
  leader's fence token and the link rejects anything older than the
  highest fence it has seen, so a deposed coordinator cannot move a
  host;
* :class:`LeaderLease` + :class:`SnapshotStore` are the in-process
  stand-ins for an etcd lease and key: a standby coordinator acquires
  the expired lease (fence strictly increases per acquisition) and
  restores the primary's snapshot.

Delivery semantics are at-least-once: the link retries sends, the
command path dedups by operation id (a retried or duplicated command
returns its cached reply instead of re-executing), and the report path
is guarded by the coordinator's stale-steps check.  Exactly-once
*delivery* is impossible under crash + loss (two generals); the fleet's
policy is to prefer a duplicate over a loss and to make re-application
idempotent.
"""
from __future__ import annotations

import dataclasses
import json
import random
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class TransportError(RuntimeError):
    """A message did not make it (drop/delay/partition/unknown peer)."""


class StaleLeaderError(TransportError):
    """A command was rejected because its fence token is older than one
    the receiver has already honoured — the sender has been deposed."""


# --------------------------------------------------------------------------
# wire encoding
# --------------------------------------------------------------------------
def to_wire(obj: Any) -> Any:
    """Normalize to plain JSON-able data: numpy arrays/scalars, tuples and
    dataclasses all become lists/dicts/python scalars."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return to_wire(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): to_wire(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_wire(v) for v in obj]
    return obj


def payload_bytes(msg: Dict[str, Any]) -> int:
    """Serialized size of a message — what a real wire would carry."""
    return len(json.dumps(to_wire(msg), separators=(",", ":"),
                          sort_keys=True, default=str))


def encode_report_delta(base: Dict[str, Any],
                        cur: Dict[str, Any]) -> Dict[str, Any]:
    """Delta-encode a full report dict against the last ACKED base.

    Only fields that changed are sent; the rolling ``batch_seconds``
    window is sent as its new tail (the ``steps`` delta counts the
    appends), and the ``io`` counter dict shrinks to its changed keys.
    """
    delta: Dict[str, Any] = {}
    for k, v in cur.items():
        if k == "batch_seconds":
            continue
        if base.get(k, "\0missing") != v:
            delta[k] = v
    if isinstance(delta.get("io"), dict) and isinstance(base.get("io"), dict):
        delta["io"] = {k: v for k, v in delta["io"].items()
                       if base["io"].get(k, "\0missing") != v}
    bs = cur.get("batch_seconds") or []
    n_new = int(cur.get("steps", 0)) - int(base.get("steps", 0))
    if bs != (base.get("batch_seconds") or []):
        tail = bs[-min(max(n_new, 0), len(bs)):] if n_new > 0 else bs
        delta["bs_tail"] = tail
        delta["bs_len"] = len(bs)
    return delta


def merge_report_delta(base: Dict[str, Any],
                       delta: Dict[str, Any]) -> Dict[str, Any]:
    """Inverse of :func:`encode_report_delta` given the same base."""
    full = dict(base)
    for k, v in delta.items():
        if k in ("bs_tail", "bs_len"):
            continue
        if k == "io" and isinstance(v, dict) \
                and isinstance(full.get("io"), dict):
            io = dict(full["io"])
            io.update(v)
            full["io"] = io
        else:
            full[k] = v
    if "bs_tail" in delta:
        merged = list(base.get("batch_seconds") or []) + list(delta["bs_tail"])
        full["batch_seconds"] = merged[-int(delta["bs_len"]):]
    return full


# --------------------------------------------------------------------------
# transports
# --------------------------------------------------------------------------
class LocalTransport:
    """In-process message fabric: named endpoints, synchronous ``call``.

    This is deliberately the *shape* of an RPC client: ``call(src, dst,
    msg) -> reply`` with :class:`TransportError` for anything that would
    be a timeout or unreachable peer.  A networked backend implements
    the same three methods.
    """

    def __init__(self):
        self._endpoints: Dict[str, Callable[[Dict[str, Any]],
                                            Dict[str, Any]]] = {}
        self.sent_msgs = 0
        self.sent_bytes = 0
        self.kind_msgs: Dict[str, int] = {}
        self.kind_bytes: Dict[str, int] = {}

    def register(self, name: str,
                 handler: Callable[[Dict[str, Any]], Dict[str, Any]],
                 *, replace: bool = False) -> None:
        if not replace and name in self._endpoints:
            raise ValueError(f"endpoint {name!r} already registered")
        self._endpoints[name] = handler

    def unregister(self, name: str) -> None:
        self._endpoints.pop(name, None)

    def endpoints(self) -> List[str]:
        return sorted(self._endpoints)

    def _account(self, msg: Dict[str, Any]) -> None:
        size = payload_bytes(msg)
        kind = str(msg.get("kind", "?"))
        self.sent_msgs += 1
        self.sent_bytes += size
        self.kind_msgs[kind] = self.kind_msgs.get(kind, 0) + 1
        self.kind_bytes[kind] = self.kind_bytes.get(kind, 0) + size

    def call(self, src: str, dst: str,
             msg: Dict[str, Any]) -> Dict[str, Any]:
        # fail fast BEFORE serialization: a refused connection costs the
        # caller nothing (a retry storm against a dead coordinator must
        # not tax the training loop), and nothing went on the wire
        handler = self._endpoints.get(dst)
        if handler is None:
            raise TransportError(f"{src} -> {dst}: no such endpoint")
        self._account(msg)
        return handler(to_wire(msg))

    def pump(self) -> int:
        """Deliver anything parked in-flight (no-op on the pure local
        fabric; :class:`FaultyTransport` delivers delayed messages)."""
        return 0


# back-compat friendly alias: the abstract protocol IS the local fabric
Transport = LocalTransport


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-call fault probabilities (seeded, deterministic)."""
    drop: float = 0.0          # message lost before the handler runs
    delay: float = 0.0         # parked; delivered stale at the next pump()
    duplicate: float = 0.0     # handler runs twice (first reply returned)
    reply_drop: float = 0.0    # handler runs, ack lost (caller sees timeout)
    seed: int = 0


class FaultyTransport(LocalTransport):
    """Seeded fault injection over :class:`LocalTransport`.

    * ``drop``       — the call raises, the handler never ran;
    * ``delay``      — the call raises NOW, the handler runs at the next
      ``pump()`` — the delayed original then arrives *after* any retry,
      which is exactly the reorder/stale-message anomaly the ingest
      guard and command dedup exist for;
    * ``duplicate``  — the handler runs twice back-to-back;
    * ``reply_drop`` — the handler ran but the caller sees a timeout —
      the fault that forces idempotent re-sends;
    * ``partition(a, b)`` — every call between a and b fails fast until
      ``heal``.
    """

    def __init__(self, faults: FaultSpec = FaultSpec()):
        super().__init__()
        self.faults = faults
        self.rng = random.Random(faults.seed)
        self._parked: List[Tuple[str, str, Dict[str, Any]]] = []
        self._cuts: set = set()
        self.dropped = 0
        self.delayed = 0
        self.duplicated = 0
        self.replies_dropped = 0

    # ---- partitions --------------------------------------------------------
    def partition(self, a: str, b: str) -> None:
        self._cuts.add(frozenset((a, b)))

    def isolate(self, name: str, others: List[str]) -> None:
        for o in others:
            self.partition(name, o)

    def heal(self, a: str, b: Optional[str] = None) -> None:
        if b is not None:
            self._cuts.discard(frozenset((a, b)))
        else:
            self._cuts = {c for c in self._cuts if a not in c}

    def heal_all(self) -> None:
        self._cuts.clear()

    def partitioned(self, a: str, b: str) -> bool:
        return frozenset((a, b)) in self._cuts

    # ---- faulted delivery --------------------------------------------------
    def call(self, src: str, dst: str,
             msg: Dict[str, Any]) -> Dict[str, Any]:
        # connection-refused paths fail fast, pre-serialization (see
        # LocalTransport.call) — and pre-rng, so the seeded fault stream
        # is independent of how often a caller retries into a partition
        if self.partitioned(src, dst):
            raise TransportError(f"{src} -> {dst}: partitioned")
        handler = self._endpoints.get(dst)
        if handler is None:
            raise TransportError(f"{src} -> {dst}: no such endpoint")
        self._account(msg)
        msg = to_wire(msg)
        f = self.faults
        r = self.rng.random()
        if r < f.drop:
            self.dropped += 1
            raise TransportError(f"{src} -> {dst}: dropped")
        if r < f.drop + f.delay:
            self.delayed += 1
            self._parked.append((src, dst, msg))
            raise TransportError(f"{src} -> {dst}: delayed (timeout)")
        if self.rng.random() < f.duplicate:
            self.duplicated += 1
            reply = handler(msg)
            handler(msg)
            return reply
        reply = handler(msg)
        if self.rng.random() < f.reply_drop:
            self.replies_dropped += 1
            raise TransportError(f"{src} -> {dst}: reply dropped")
        return reply

    def pump(self) -> int:
        """Deliver every parked (delayed) message; replies are discarded
        — from the receiver's view these are stale retransmits."""
        parked, self._parked = self._parked, []
        n = 0
        for src, dst, msg in parked:
            if self.partitioned(src, dst):
                continue
            handler = self._endpoints.get(dst)
            if handler is None:
                continue
            try:
                handler(msg)
                n += 1
            except Exception:
                pass
        return n


# --------------------------------------------------------------------------
# leader election + snapshots (in-process etcd stand-ins)
# --------------------------------------------------------------------------
class LeaderLease:
    """TTL lease with a monotonically increasing fence token.

    ``acquire`` grants the lease when it is free/expired (bumping the
    fence) or refreshes it for the current holder (same fence).  Any
    command stamped with fence ``f`` is provably from the leader of
    lease generation ``f``; receivers reject ``f' < f_seen`` — the
    classic fencing-token construction.
    """

    def __init__(self, *, ttl_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.ttl_s = ttl_s
        self.clock = clock
        self._owner: Optional[str] = None
        self._expires = float("-inf")
        self._fence = 0

    def acquire(self, owner: str) -> Optional[int]:
        now = self.clock()
        if self._owner == owner:
            self._expires = now + self.ttl_s
            return self._fence
        if self._owner is None or now > self._expires:
            self._owner = owner
            self._expires = now + self.ttl_s
            self._fence += 1
            return self._fence
        return None

    def refresh(self, owner: str) -> bool:
        if self._owner == owner and self.clock() <= self._expires:
            self._expires = self.clock() + self.ttl_s
            return True
        return False

    def release(self, owner: str) -> None:
        if self._owner == owner:
            self._owner = None
            self._expires = float("-inf")

    def holder(self) -> Optional[str]:
        if self._owner is not None and self.clock() > self._expires:
            return None
        return self._owner

    @property
    def fence(self) -> int:
        return self._fence


class SnapshotStore:
    """Versioned single-key snapshot store (the etcd key the coordinator
    checkpoints into).  Values are wire-normalized on put so a restore
    can never alias live coordinator state."""

    def __init__(self):
        self._value: Optional[Dict[str, Any]] = None
        self.seq = 0

    def put(self, state: Dict[str, Any]) -> int:
        self._value = to_wire(state)
        self.seq += 1
        return self.seq

    def get(self) -> Optional[Dict[str, Any]]:
        return None if self._value is None else to_wire(self._value)


# --------------------------------------------------------------------------
# the host side: AgentLink
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LinkConfig:
    max_queue: int = 64          # bounded: a long partition drops OLDEST
    retries: int = 6             # immediate retransmits per send
    backoff_s: float = 0.05     # first backoff after retries exhausted
    backoff_mult: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.5          # +[0, jitter) * backoff, seeded
    dedup_cache: int = 512       # remembered command replies
    seed: int = 0


class AgentLink:
    """One host's connection to the coordinator endpoint.

    Outbound (reports): bounded queue + exponential backoff with jitter;
    a report that cannot be sent is parked, training is NEVER blocked.
    On reconnect the parked backlog is replayed in order (the
    coordinator's stale-steps guard makes replay harmless) and the
    current report re-syncs the host.  Reports are delta-encoded against
    the last acked base; the coordinator answers ``need_full`` when its
    base disagrees (e.g. after a failover), which forces one full resend
    — the delta protocol is self-healing.

    Inbound (commands): fence check first — a command whose fence is
    below the highest this link has seen is rejected and recorded
    (``rejected``); then op-id dedup — a duplicated/replayed command
    returns its cached reply instead of executing twice.
    """

    def __init__(self, transport: LocalTransport, host: str, *,
                 coord: str = "coord", config: Optional[LinkConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.transport = transport
        self.host = host
        self.coord = coord
        self.cfg = config or LinkConfig()
        self.clock = clock
        # stable per-host seed (str.__hash__ is process-randomized)
        self.rng = random.Random(
            self.cfg.seed * 1000003 + sum(ord(c) for c in host))
        self.agent: Any = None
        # fencing: highest leader fence seen; stale commands are rejected
        self.fence = -1
        self.rejected: List[Dict[str, Any]] = []
        self._done: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        # outbound report queue
        self._pending: deque = deque(maxlen=self.cfg.max_queue)
        self._last_acked: Optional[Dict[str, Any]] = None
        self._force_full = True
        self._backoff = self.cfg.backoff_s
        self._next_try = 0.0
        self.connected = False
        self.evicted = False
        # counters (tests + benches)
        self.full_sent = 0
        self.delta_sent = 0
        self.dropped_reports = 0
        self.send_failures = 0

    # ---- lifecycle ---------------------------------------------------------
    def bind(self, agent: Any) -> "AgentLink":
        """Attach the host agent: the link becomes its transport endpoint
        and dispatches inbound commands to ``agent.handle_command``."""
        self.agent = agent
        self.transport.register(self.host, self._handle, replace=True)
        return self

    def register(self) -> Dict[str, Any]:
        """Announce this host to the coordinator (member spec crosses as
        data — the coordinator builds its shard-map mirror from it)."""
        reply = self._call_retry({"kind": "register", "host": self.host,
                                 "spec": to_wire(self.agent.member_spec())})
        self._saw_fence(reply)
        self.connected = True
        self.evicted = False
        return reply

    def join(self) -> Dict[str, Any]:
        """Mid-run admission: the coordinator reshards incumbents and
        aligns this host at the returned barrier (via commands back over
        this same link)."""
        reply = self._call_retry({"kind": "join", "host": self.host,
                                 "spec": to_wire(self.agent.member_spec())})
        self._saw_fence(reply)
        self.connected = True
        self.evicted = False
        return reply

    def leave(self) -> None:
        try:
            self._call_retry({"kind": "leave", "host": self.host})
        except TransportError:
            pass

    # ---- outbound: reports -------------------------------------------------
    def send_report(self, full: Dict[str, Any]) -> bool:
        """Queue + try to deliver one full report dict.  Returns True when
        the coordinator acked it (False = parked for replay; training
        continues on latched params either way)."""
        if self.evicted:
            return False
        if len(self._pending) == self._pending.maxlen:
            self.dropped_reports += 1
        self._pending.append(to_wire(full))
        if self.clock() < self._next_try:
            return False
        return self._flush()

    def beat(self) -> bool:
        """Cheap liveness when there is no observation to report."""
        if self.evicted:
            return False
        try:
            reply = self.transport.call(
                self.host, self.coord,
                {"kind": "beat", "host": self.host})
            self._saw_fence(reply)
            return bool(reply.get("ok"))
        except TransportError:
            return False

    def cast(self, kind: str, **fields: Any) -> bool:
        """One-way best-effort message (drift signals, locality
        proposals) — losing one is safe, the condition re-fires."""
        try:
            self.transport.call(self.host, self.coord,
                                {"kind": kind, "host": self.host, **fields})
            return True
        except TransportError:
            return False

    def _flush(self) -> bool:
        if not self._pending:
            return True
        base = self._last_acked
        if len(self._pending) == 1 and base is not None \
                and not self._force_full:
            cur = self._pending[-1]
            msg = {"kind": "report", "host": self.host, "delta": True,
                   "base": int(base.get("steps", -1)),
                   "patch": encode_report_delta(base, cur)}
        else:
            msg = {"kind": "report", "host": self.host,
                   "reports": list(self._pending)}
        reply = self._try_call(msg)
        if reply is None:
            self._on_send_failure()
            return False
        self._saw_fence(reply)
        if reply.get("evicted"):
            # the coordinator resharded around us during a partition; our
            # shard no longer exists.  Stop reporting — the driver decides
            # whether to rejoin (with a fresh stream) via ``join()``.
            self.evicted = True
            self.connected = False
            self._pending.clear()
            return False
        if reply.get("need_full"):
            # coordinator lost our delta base (failover) — resend full
            self._force_full = True
            msg = {"kind": "report", "host": self.host,
                   "reports": list(self._pending)}
            reply = self._try_call(msg)
            if reply is None:
                self._on_send_failure()
                return False
            self._saw_fence(reply)
        if reply.get("ok"):
            if msg.get("delta"):
                self.delta_sent += 1
            else:
                self.full_sent += 1
            self._last_acked = self._pending[-1]
            self._pending.clear()
            self._force_full = False
            self._backoff = self.cfg.backoff_s
            self._next_try = 0.0
            self.connected = True
            return True
        return False

    def _try_call(self, msg: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        for _ in range(max(1, self.cfg.retries)):
            try:
                return self.transport.call(self.host, self.coord, msg)
            except TransportError:
                continue
        return None

    def _call_retry(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        reply = self._try_call(msg)
        if reply is None:
            raise TransportError(
                f"{self.host}: {msg.get('kind')} to {self.coord} failed "
                f"after {self.cfg.retries} retries")
        return reply

    def _on_send_failure(self) -> None:
        self.send_failures += 1
        self.connected = False
        jitter = 1.0 + self.cfg.jitter * self.rng.random()
        self._next_try = self.clock() + self._backoff * jitter
        self._backoff = min(self.cfg.max_backoff_s,
                            self._backoff * self.cfg.backoff_mult)

    def _saw_fence(self, reply: Dict[str, Any]) -> None:
        f = reply.get("fence")
        if f is not None:
            self.fence = max(self.fence, int(f))

    # ---- inbound: fenced, idempotent command dispatch ----------------------
    def _handle(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        kind = msg.get("kind")
        if kind == "ping":
            return {"ok": True, "fence": self.fence, "host": self.host}
        if kind != "cmd":
            return {"ok": False, "error": f"unknown kind {kind!r}"}
        fence = int(msg.get("fence", -1))
        if fence < self.fence:
            self.rejected.append({"op": msg.get("op"), "fence": fence,
                                  "current": self.fence,
                                  "id": msg.get("id")})
            return {"ok": False, "error": "stale-fence", "fence": self.fence}
        self.fence = fence
        oid = msg.get("id")
        if oid is not None and oid in self._done:
            return self._done[oid]
        try:
            result = self.agent.handle_command(msg.get("op"),
                                               msg.get("args") or {})
            reply = {"ok": True, "result": to_wire(result),
                     "fence": self.fence}
        except Exception as e:  # surfaced to the sender, not raised here
            reply = {"ok": False,
                     "error": f"{type(e).__name__}: {e}",
                     "fence": self.fence}
        if oid is not None:
            self._done[oid] = reply
            while len(self._done) > self.cfg.dedup_cache:
                self._done.popitem(last=False)
        return reply
