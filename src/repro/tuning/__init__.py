"""repro.tuning — the unified tuning layer.

``tune(evaluator=..., strategy=..., config=...)`` is the single front door
to every search policy (see ``base.py``); ``OnlineTuner`` turns tuning into
a continuous background activity against a live, hot-swappable DataLoader
(``online.py``: split into observe/decide/act components); the fleet
control plane (``fleet.py``: HostAgent + FleetCoordinator) recomposes
those components across hosts — coordinated re-consensus and elastic
resharding.  Strategy implementations live in ``strategies.py``
and self-register; third-party strategies register the same way::

    from repro.tuning import register_strategy

    @register_strategy("my_policy")
    class MyPolicy:
        def tune(self, recorder, **kwargs): ...
"""
from repro.tuning.base import (  # noqa: F401
    TrialRecorder,
    TuningStrategy,
    adaptive_budget,
    available_strategies,
    get_strategy,
    register_strategy,
    tune,
    welch_wins,
    worker_rungs,
)
from repro.tuning.strategies import (  # noqa: F401
    CostModelPrediction,
    GoodputTune,
    GridSearch,
    HillClimb,
    SuccessiveHalving,
    WarmstartHillClimb,
    cost_model_warmstart,
)
from repro.tuning.locality import (  # noqa: F401
    AdaptiveLocalityConfig,
    AdaptiveLocalityController,
    cache_win,
    locality_win,
    slow_lane_win,
    sweep_cache,
    sweep_locality,
    sweep_slow_lanes,
)
from repro.tuning.online import (  # noqa: F401
    GoodputMonitor,
    OnlineTuner,
    OnlineTunerConfig,
    RetuneExecutor,
    RetunePolicy,
)
from repro.tuning.transport import (  # noqa: F401
    AgentLink,
    FaultSpec,
    FaultyTransport,
    LeaderLease,
    LinkConfig,
    LocalTransport,
    SnapshotStore,
    StaleLeaderError,
    Transport,
    TransportError,
)
from repro.tuning.fleet import (  # noqa: F401
    CoordinatorReplica,
    CoordinatorServer,
    FleetConfig,
    FleetCoordinator,
    HostAgent,
    HostReport,
    RemoteAgent,
    connect_host,
    uniform_consensus,
)
