"""repro.tuning — the unified tuning layer.

``tune(evaluator=..., strategy=..., config=...)`` is the single front door
to every search policy (see ``base.py``); ``OnlineTuner`` turns tuning into
a continuous background activity against a live, hot-swappable DataLoader
(see ``online.py``).  Strategy implementations live in ``strategies.py``
and self-register; third-party strategies register the same way::

    from repro.tuning import register_strategy

    @register_strategy("my_policy")
    class MyPolicy:
        def tune(self, recorder, **kwargs): ...
"""
from repro.tuning.base import (  # noqa: F401
    TrialRecorder,
    TuningStrategy,
    available_strategies,
    get_strategy,
    register_strategy,
    tune,
    worker_rungs,
)
from repro.tuning.strategies import (  # noqa: F401
    CostModelPrediction,
    GoodputTune,
    GridSearch,
    HillClimb,
    SuccessiveHalving,
    WarmstartHillClimb,
    cost_model_warmstart,
)
from repro.tuning.online import OnlineTuner, OnlineTunerConfig  # noqa: F401
