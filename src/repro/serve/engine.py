"""Batched serving engine: prefill + jit'd decode loop with donated KV cache,
plus a request-batching frontend.

The decode step is the exact function the decode_* dry-run cells lower —
one new token against a seq_len-sized cache — so what we roofline is what
we serve.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray           # (B, <=max_new_tokens) generated ids
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def tokens_per_second(self) -> float:
        n = self.tokens.shape[0] * self.steps
        return n / self.decode_s if self.decode_s > 0 else 0.0


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 temperature: float = 0.0, eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id

        self._prefill = jax.jit(self.model.prefill)

        def _decode(params, cache, tokens, positions):
            logits, cache = self.model.decode_step(params, cache, tokens,
                                                   positions)
            return logits, cache

        # donate the cache: decode updates it in place on device
        self._decode = jax.jit(_decode, donate_argnums=(1,))

    def _sample(self, logits, rng):
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, logits / self.temperature) \
            .astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 *, seed: int = 0, extra_inputs: Optional[dict] = None
                 ) -> GenerateResult:
        """prompts: (B, S) int32, right-aligned (no padding support needed
        for the fixed-shape engine: all prompts same length)."""
        B, S = prompts.shape
        assert B <= self.max_batch, (B, self.max_batch)
        assert S + max_new_tokens <= self.max_len

        cache = self.model.init_cache(B, self.max_len)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache)
        logits = jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        rng = jax.random.PRNGKey(seed)
        tok = self._sample(logits, rng)
        out = [np.asarray(tok)]
        positions = jnp.full((B,), S, jnp.int32)
        done = np.zeros(B, bool)

        t1 = time.perf_counter()
        steps = 0
        for i in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, cache, tok[:, None],
                                         positions)
            tok = self._sample(logits, sub)
            positions = positions + 1
            steps += 1
            host_tok = np.asarray(tok)
            out.append(host_tok)
            if self.eos_id is not None:
                done |= host_tok == self.eos_id
                if done.all():
                    break
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        return GenerateResult(np.stack(out, axis=1), t_prefill, t_decode,
                              steps + 1)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int
    result: "queue.Queue" = dataclasses.field(
        default_factory=lambda: queue.Queue(maxsize=1))
    # submission wall time (set by BatchingFrontend.submit): the batch
    # assembly wait — submit to generate-start — is measured from this
    t_submit: float = 0.0


class BatchMixMonitor:
    """Detects drift in the mix of served batch shapes and fires a retune.

    Serving goodput depends on the request mix: a shift from short-prompt
    to long-prompt traffic (or a new modality) changes how much host-side
    preprocessing each batch needs, which invalidates a tuned loader
    config.  The frontend records one shape key per batch served; when the
    bucketed distribution over the last ``window`` batches diverges from
    the previous window by more than ``threshold`` (half the L1 distance,
    in [0, 1]), ``on_drift`` fires with the new mix distribution.  Typical
    wiring to the online tuner::

        BatchMixMonitor(
            on_drift=lambda mix: tuner.force_retune(reason="batch-mix"))

    so the feature loader re-searches with a small budget and hot-swaps
    (see repro.tuning.online).  Callback errors are contained by the
    serving thread (reported to stderr), never fatal to serving.
    """

    def __init__(self, *, window: int = 32, threshold: float = 0.35,
                 cooldown: int = 64, on_drift=None):
        self.window = window
        self.threshold = threshold
        self.cooldown = cooldown
        self.on_drift = on_drift
        self._recent: List = []
        self._baseline: Optional[dict] = None
        self._since_fire = 0
        self.drifts = 0

    @staticmethod
    def _dist(keys) -> dict:
        d: dict = {}
        for k in keys:
            d[k] = d.get(k, 0) + 1
        n = max(1, len(keys))
        return {k: v / n for k, v in d.items()}

    @staticmethod
    def divergence(a: dict, b: dict) -> float:
        """Half the L1 distance between two mix distributions (0..1)."""
        keys = set(a) | set(b)
        return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)

    def record(self, shape_key) -> bool:
        """One call per batch served; returns True when drift fired."""
        self._recent.append(shape_key)
        self._since_fire += 1
        if len(self._recent) < self.window:
            return False
        current = self._dist(self._recent[-self.window:])
        if self._baseline is None:
            self._baseline = current
            self._recent = self._recent[-self.window:]
            return False
        self._recent = self._recent[-self.window:]
        if self._since_fire < self.cooldown:
            return False
        if self.divergence(self._baseline, current) <= self.threshold:
            return False
        self._baseline = current
        self._since_fire = 0
        self.drifts += 1
        if self.on_drift is not None:
            self.on_drift(current)
        return True


class BatchingFrontend:
    """Collects requests into batches (size- or timeout-triggered) and runs
    them through the engine — the 'serve a small model with batched
    requests' driver.  An optional BatchMixMonitor watches the served
    shape mix and triggers loader retuning when traffic drifts.

    Fleet wiring: given a ``repro.tuning.fleet.HostAgent`` (construct it
    with ``consumes_stream=False`` — serving observes per request-group,
    not per loader batch, so the agent must take its consumed position
    from the stream cursor), every served batch feeds the agent's goodput
    monitor (data-wait = batch formation time, compute = generate time)
    and doubles as the host's heartbeat, so the FleetCoordinator sees a
    serving host exactly like a training host.  The usual mix-monitor
    hookup becomes
    ``BatchMixMonitor(on_drift=lambda mix: agent.notify_drift("batch-mix"))``
    — the coordinator then runs the fleet-wide re-consensus instead of a
    host-local retune.  A re-consensus may also carry the cross-epoch
    cache budget (DESIGN.md §7): the push arrives through the same
    ``agent.apply_params`` hot swap and resizes the feature loader's
    cache tier in place — a long-lived serving host keeps its warm
    entries across the retune.

    Dual-lane serving (DESIGN.md §9): with ``slow_lane=True`` a
    dedicated slow-group thread serves request groups whose predicted
    cost (a :class:`repro.data.costs.KeyedCostTracker` EWMA keyed by
    ``(prompt_len, max_new_tokens)``) is a tail outlier, so a burst of
    cheap requests never queues behind one expensive group — the cheap
    traffic keeps its p99 batch-assembly latency
    (``assembly_wait_p99()``)."""

    def __init__(self, engine: ServeEngine, *, max_wait_s: float = 0.01,
                 mix_monitor: Optional[BatchMixMonitor] = None,
                 agent=None, locality_controller=None,
                 slow_lane: bool = False, slow_threshold: float = 4.0,
                 feature_loader=None, fault_rate_trigger: float = 0.0,
                 on_fault=None):
        from repro.data.costs import KeyedCostTracker
        self.engine = engine
        self.max_wait_s = max_wait_s
        self.mix_monitor = mix_monitor
        self.agent = agent
        # fault plane (DESIGN.md §10): poll the feature loader's
        # io_counters every ~16 served batches; edge-triggered on_fault
        # callback ("fault-drift" entering an excursion, "fault-heal"
        # leaving) — typical hookup is agent.notify_drift or a host-local
        # tuner.force_retune, exactly like the mix monitor
        self.feature_loader = feature_loader
        self.fault_rate_trigger = float(fault_rate_trigger)
        self.on_fault = on_fault
        self._faulted = False
        self.fault_events = 0
        # the online locality loop's counter-driven side (DESIGN.md §6):
        # a repro.tuning.AdaptiveLocalityController built over the feature
        # loader; stepped once per served batch inside the same guarded
        # block as observe/record (a resize proposal must never kill the
        # serving thread)
        self.locality_controller = locality_controller
        self.slow_lane = slow_lane
        self.cost_tracker = KeyedCostTracker(threshold=slow_threshold)
        self._queue: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        # per-request assembly waits (submit -> generate start), split by
        # the lane that served them; bounded reservoirs for the p99
        self._wait_fast: List[float] = []
        self._wait_slow: List[float] = []
        self._wait_lock = threading.Lock()
        self._slow_queue: queue.Queue = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._slow_thread: Optional[threading.Thread] = None
        if slow_lane:
            self._slow_thread = threading.Thread(target=self._run_slow,
                                                 daemon=True)
            self._slow_thread.start()
        self.batches_served = 0
        self.slow_groups = 0

    def connect_fleet(self, transport, loader, *, host: str = "serve0",
                      join: bool = False, coord: str = "coord",
                      link_config=None, clock=time.monotonic):
        """Attach this frontend to a fleet over a message transport: the
        serving host then reports/heartbeats over the wire exactly like a
        training host (``consumes_stream=False`` — serving observes per
        request-group, so loader consumption comes from the stream
        cursor).  A coordinator outage never stalls serving; the host
        keeps batching on its last latched params."""
        from repro.tuning.fleet import connect_host
        self.agent = connect_host(
            transport, host, loader, coord=coord, link_config=link_config,
            clock=clock, join=join, consumes_stream=False)
        return self.agent

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        req = Request(np.asarray(prompt, np.int32), max_new_tokens,
                      t_submit=time.perf_counter())
        self._queue.put(req)
        return req

    def assembly_wait_p99(self, *, slow: bool = False) -> float:
        """p99 of per-request assembly wait (submit to generate start) for
        the fast lane — or, with ``slow=True``, the slow lane."""
        from repro.data.costs import percentile
        with self._wait_lock:
            samples = list(self._wait_slow if slow else self._wait_fast)
        return percentile(samples, 0.99)

    def _drain_batch(self) -> List[Request]:
        reqs: List[Request] = []
        try:
            reqs.append(self._queue.get(timeout=0.1))
        except queue.Empty:
            return reqs
        deadline = time.perf_counter() + self.max_wait_s
        while (len(reqs) < self.engine.max_batch
               and time.perf_counter() < deadline):
            try:
                reqs.append(self._queue.get_nowait())
            except queue.Empty:
                time.sleep(0.001)
        return reqs

    def _run(self):
        while not self._stop.is_set():
            t0 = time.perf_counter()
            reqs = self._drain_batch()
            if not reqs:
                if self.agent is not None:
                    self.agent.heartbeat()    # idle != dead
                continue
            t_form = time.perf_counter() - t0
            # group by (prompt_len, max_new) to keep shapes static
            by_shape = {}
            for r in reqs:
                by_shape.setdefault(
                    (len(r.prompt), r.max_new_tokens), []).append(r)
            for (plen, max_new), group in by_shape.items():
                if self.slow_lane and self.cost_tracker.is_slow(
                        (plen, max_new)):
                    # predicted-expensive group: hand it to the slow
                    # thread so the cheap traffic behind it keeps its p99
                    self.slow_groups += 1
                    self._slow_queue.put((plen, max_new, group, t_form))
                else:
                    self._serve_group(plen, max_new, group, t_form,
                                      lane_slow=False)
                t_form = 0.0        # only the first group pays formation

    def _run_slow(self):
        while not self._stop.is_set():
            try:
                plen, max_new, group, t_form = self._slow_queue.get(
                    timeout=0.1)
            except queue.Empty:
                continue
            self._serve_group(plen, max_new, group, t_form, lane_slow=True)

    def _poll_faults(self) -> None:
        """Edge-triggered fault watch on the feature loader (DESIGN.md
        §10): fires ``on_fault(reason, io)`` once entering an excursion
        and once on heal, never continuously."""
        io = self.feature_loader.io_counters() or {}
        faulted = (io.get("fault_rate", 0.0) > self.fault_rate_trigger
                   or io.get("degraded", 0.0) >= 1.0)
        if faulted == self._faulted:
            return
        self._faulted = faulted
        self.fault_events += 1
        if self.on_fault is not None:
            self.on_fault("fault-drift" if faulted else "fault-heal", io)

    def _serve_group(self, plen: int, max_new: int, group: List[Request],
                     t_form: float, *, lane_slow: bool) -> None:
        prompts = np.stack([r.prompt for r in group])
        t1 = time.perf_counter()
        waits = [max(0.0, t1 - r.t_submit) for r in group if r.t_submit > 0]
        res = self.engine.generate(prompts, max_new)
        t_gen = time.perf_counter() - t1
        self.batches_served += 1
        try:
            # per-request cost estimate feeds next dispatch's routing
            self.cost_tracker.record((plen, max_new), t_gen / len(group))
            with self._wait_lock:
                reservoir = self._wait_slow if lane_slow else self._wait_fast
                reservoir.extend(waits)
                del reservoir[:-512]
            if self.agent is not None:
                # batch formation is the serving analogue of the
                # trainer's data wait; generate is the compute
                self.agent.observe(data_s=t_form, step_s=t_form + t_gen)
            if self.mix_monitor is not None:
                self.mix_monitor.record((plen, max_new))
            if self.locality_controller is not None:
                self.locality_controller.step()
            if (self.feature_loader is not None
                    and self.fault_rate_trigger > 0.0
                    and self.batches_served % 16 == 0):
                self._poll_faults()
        except Exception:  # noqa: BLE001 - observe/retune must not
            import traceback  # kill the serving thread
            traceback.print_exc()
        for i, r in enumerate(group):
            r.result.put(res.tokens[i])

    def shutdown(self):
        self._stop.set()
        self._thread.join(timeout=5)
        if self._slow_thread is not None:
            self._slow_thread.join(timeout=5)
