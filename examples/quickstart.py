"""Quickstart: tune a real dataloader with DPT (paper Algorithm 1).

Builds a synthetic image dataset behind a latency-injected storage layer,
runs the grid search over (num_workers, prefetch_factor) with the actual
thread-pool loader (wall clock, device transfer included), and prints the
tuned parameters vs the framework default.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DPT, DPTConfig, LoaderEvaluator, default_params
from repro.data.dataset import Dataset, image_transform
from repro.data.loader import DataLoader, LoaderParams
from repro.data.storage import ArrayStorage, LatencyStorage


def main() -> None:
    # 512 synthetic 128x128 images behind a 2ms-latency storage layer
    rng = np.random.default_rng(0)
    items = [rng.integers(0, 255, (128, 128, 3), dtype=np.uint8)
             for _ in range(512)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=2e-3,
                             bandwidth=400e6)
    dataset = Dataset(storage, transform=image_transform)
    loader = DataLoader(dataset, global_batch=32, shuffle=True)

    print("== DPT (Algorithm 1): grid search over (nWorker, nPrefetch) ==")
    evaluator = LoaderEvaluator(loader, to_device=True)
    dpt = DPT(evaluator, DPTConfig(num_cpu_cores=8, num_devices=1,
                                   max_prefetch=4, num_batches=8))
    result = dpt.run()

    dw, dp = default_params(8)
    print(f"cells measured : {len(result.trials)}")
    print(f"default params : workers={dw} prefetch={dp} "
          f"-> {result.default_time:.3f}s")
    print(f"tuned params   : workers={result.nworker} "
          f"prefetch={result.nprefetch} -> {result.optimal_time:.3f}s")
    print(f"speedup        : {result.speedup_vs_default:.2f}x")

    print("\n== tuned loader in use ==")
    loader.with_params(LoaderParams(num_workers=result.nworker,
                                    prefetch_factor=result.nprefetch))
    stats = loader.measure_transfer_time(16, to_device=True)
    print(f"delivered {stats.batches} batches, "
          f"{stats.bytes / 1e6:.1f} MB at "
          f"{stats.bytes_per_second / 1e6:.1f} MB/s")


if __name__ == "__main__":
    main()
