"""The paper's full workflow on the calibrated testbed model: reproduce the
CIFAR-10 grid (Fig 2) and a slice of the COCO resolution study (Table 1),
then show the beyond-paper tuners finding the same optimum for a fraction
of the measurements.

    PYTHONPATH=src python examples/tune_dataloader.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        SimulatorEvaluator, default_params)
from repro.core.search import successive_halving, tuned_with_warmstart
from repro.data.storage import cifar10_profile, coco_profile

MACHINE = MachineProfile()    # the paper's i7-8700K / 64 GB / 1 GPU testbed


def tune(profile, batch, epoch, label):
    ev = SimulatorEvaluator(LoaderSimulator(profile, MACHINE),
                            batch_size=batch)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                    num_batches=48, epoch=epoch)
    res = DPT(ev, cfg).run()
    print(f"{label:24s} optimal=({res.nworker:2d},{res.nprefetch})  "
          f"default={default_params(12)}  "
          f"speedup={res.speedup_vs_default:.2f}x  "
          f"cells={len(res.trials)}")
    return ev, cfg, res


def main() -> None:
    print("== CIFAR-10 (paper Fig 2: optimum ~10 workers, ~1.3x) ==")
    tune(cifar10_profile(), 32, epoch=1, label="cifar10 b32 warm")

    print("\n== COCO resolutions (paper Table 1 regimes) ==")
    for res_px in (80, 160, 320, 640):
        tune(coco_profile(res_px), 32, epoch=0,
             label=f"coco {res_px}px b32 cold")
    tune(coco_profile(80), 32, epoch=1, label="coco 80px b32 warm")

    print("\n== beyond-paper: same optimum, fewer measurements ==")
    storage = coco_profile(160)
    ev = SimulatorEvaluator(LoaderSimulator(storage, MACHINE), batch_size=32)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                    num_batches=48, epoch=0)
    grid = DPT(ev, cfg).run(measure_default=False)
    grid_cost = ev.calls

    ev2 = SimulatorEvaluator(LoaderSimulator(storage, MACHINE), batch_size=32)
    sh = successive_halving(ev2, config=cfg)
    ev3 = SimulatorEvaluator(LoaderSimulator(storage, MACHINE), batch_size=32)
    hc = tuned_with_warmstart(ev3, storage, MACHINE, batch_size=32,
                              config=cfg)
    print(f"grid search     : ({grid.nworker},{grid.nprefetch}) "
          f"in {grid_cost} measurements")
    print(f"succ. halving   : ({sh.nworker},{sh.nprefetch}) "
          f"in {ev2.calls} cheaper measurements")
    print(f"warm+hillclimb  : ({hc.nworker},{hc.nprefetch}) "
          f"in {ev3.calls} measurements")


if __name__ == "__main__":
    main()
