"""Serve a small model with batched requests: prefill + KV-cache decode
through the ServeEngine, then concurrent clients through the
BatchingFrontend (requests arriving within a window are batched together).

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-1.7b
        (reduced same-family config of any assigned arch)
"""
import argparse
import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import build_model
from repro.serve.engine import BatchingFrontend, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    engine = ServeEngine(model, params, max_batch=8,
                         max_len=args.prompt_len + args.new_tokens,
                         temperature=0.8)

    # --- direct batched generate ------------------------------------------
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (4, args.prompt_len)).astype(np.int32)
    r = engine.generate(prompts, args.new_tokens)
    print(f"\nbatched generate: {r.tokens.shape[0]} seqs x "
          f"{r.tokens.shape[1]} new tokens | prefill {r.prefill_s*1e3:.0f} ms"
          f" | decode {r.decode_s*1e3:.0f} ms "
          f"({r.tokens_per_second:.0f} tok/s)")
    print("first sequence:", r.tokens[0, :12], "...")

    # --- concurrent clients through the batching frontend -------------------
    fe = BatchingFrontend(engine, max_wait_s=0.05)
    results = {}

    def client(i):
        p = rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32)
        req = fe.submit(p, args.new_tokens)
        results[i] = req.result.get(timeout=300)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.shutdown()
    sizes = {i: (v.shape if v is not None else None)
             for i, v in sorted(results.items())}
    print(f"\nfrontend served {len(results)} concurrent requests: {sizes}")
    assert all(v is not None for v in results.values())
    print("OK")


if __name__ == "__main__":
    main()
