"""Online retuning of a LIVE DataLoader — the paper's tuner, made continuous.

A real (wall-clock, thread-parallel) loader streams batches while a fake
training loop consumes them.  Mid-run the storage degrades (latency x8,
bandwidth /8 — a noisy co-tenant stealing the disk).  The OnlineTuner
notices the goodput stall, runs a bounded hillclimb against the live
loader, and hot-swaps the winner in WITHOUT restarting the stream: the old
worker pool is drained at a batch boundary, the sampler position is kept,
zero batches are lost.

    PYTHONPATH=src python examples/online_tuning.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.evaluators import LoaderEvaluator
from repro.data import DataLoader, Dataset, LoaderParams
from repro.data.dataset import image_transform
from repro.data.storage import ArrayStorage, LatencyStorage
from repro.tuning import OnlineTuner, OnlineTunerConfig

STEPS = 200
DRIFT_AT = 40
COMPUTE_S = 0.006          # fake model step


def main() -> None:
    rng = np.random.default_rng(0)
    items = [rng.integers(0, 255, (16, 16, 3), dtype=np.uint8)
             for _ in range(4096)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=0.2e-3,
                             bandwidth=1e9, concurrent_streams=32)
    ds = Dataset(storage, transform=image_transform)
    dl = DataLoader(ds, 16, params=LoaderParams(num_workers=8,
                                                prefetch_factor=2), seed=0)

    tuner = OnlineTuner(
        dl, evaluator=LoaderEvaluator(dl, to_device=False),
        config=OnlineTunerConfig(stall_fraction=0.2, window=8,
                                 warmup_steps=16, cooldown_steps=12,
                                 retune_budget_batches=32, max_prefetch=4,
                                 min_improvement=0.25,  # wall-clock noise
                                 num_cpu_cores=16, num_devices=2))

    stream = dl.stream(to_device=False)
    phase_times = {"healthy": [], "drifted": [], "recovered": []}
    retunes_before_drift = 0
    for step in range(STEPS):
        if step == DRIFT_AT:
            retunes_before_drift = tuner.retunes
            storage.latency_s *= 40
            storage.bandwidth /= 4
            print(f"-- step {step}: storage degraded (latency x40, bw /4)")
        t0 = time.perf_counter()
        _batch = next(stream)
        data_s = time.perf_counter() - t0
        time.sleep(COMPUTE_S)
        step_s = time.perf_counter() - t0
        applied = tuner.observe(data_s=data_s, step_s=step_s)
        if applied is not None:
            print(f"-- step {step}: retuned -> workers={applied.num_workers} "
                  f"prefetch={applied.prefetch_factor} "
                  f"(swap #{stream.swaps + 1} pending at batch boundary)")
        phase = ("healthy" if step < DRIFT_AT else
                 "drifted" if tuner.retunes == retunes_before_drift
                 else "recovered")
        phase_times[phase].append(step_s)

    for phase, ts in phase_times.items():
        if ts:
            print(f"{phase:10s} steps={len(ts):3d}  "
                  f"mean step={1e3 * np.mean(ts):6.2f} ms  "
                  f"throughput={16 / np.mean(ts):8.1f} img/s")
    print(f"retunes={tuner.retunes}  completed hot swaps={stream.swaps}  "
          f"final params=({dl.params.num_workers},"
          f"{dl.params.prefetch_factor})")
    for ev in tuner.history:
        print(f"  search @step {ev['step']} [{ev['outcome']:7s}]: "
              f"{ev['params']} after {ev['measurements']} measurements "
              f"({ev['search_s']:.2f}s search)")


if __name__ == "__main__":
    main()
