"""End-to-end driver: train a decoder LM with the DPT-tuned data pipeline,
async checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py                 # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # TPU-sized cfg
    PYTHONPATH=src python examples/train_lm.py --resume        # restart demo

The smoke preset (~3M params) runs a few hundred steps in minutes on this
CPU container; the 100m preset is the same code at a ~100M-param config
(what you would launch on a v5e slice via repro.launch.train).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.dataset import Dataset
from repro.data.loader import DataLoader
from repro.data.storage import ArrayStorage


def lcg_dataset(num_items: int, seq_len: int, vocab: int, seed: int = 0):
    """Learnable synthetic LM data: next token = (a*t + c) mod V, random
    start — the model can drive the loss toward 0 (uniform-random tokens sit
    at the ln(V) entropy floor and show no learning signal)."""
    rng = np.random.default_rng(seed)
    a, c = 5, 17
    items = []
    for _ in range(num_items):
        seq = np.empty(seq_len + 1, np.int64)
        seq[0] = rng.integers(0, vocab)
        for i in range(seq_len):
            seq[i + 1] = (a * seq[i] + c) % vocab
        items.append(seq.astype(np.int32))

    def transform(arr):
        return {"tokens": arr[:-1], "targets": arr[1:],
                "loss_mask": np.ones(seq_len, np.float32)}

    return Dataset(ArrayStorage(items), transform=transform)
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~3M params: runs a few hundred steps on 1 CPU core in minutes
    "smoke": dict(num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
                  head_dim=32, d_ff=512, vocab_size=2048, seq=128, batch=8,
                  steps=200),
    # ~100M params (GPT-2-medium-ish): the config the assignment's end-to-end
    # driver targets; identical code path, sized for a real accelerator
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768, seq=1024, batch=32,
                 steps=300),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]

    cfg = ModelConfig(
        name=f"example-{args.preset}", family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"])
    model = build_model(cfg)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")

    dataset = lcg_dataset(1024, p["seq"], p["vocab_size"])
    loader = DataLoader(dataset, global_batch=p["batch"], seed=0)

    if not args.resume and os.path.isdir(args.ckpt_dir):
        import shutil
        shutil.rmtree(args.ckpt_dir)

    tcfg = TrainerConfig(
        total_steps=steps,
        checkpoint_every=max(25, steps // 4),
        checkpoint_dir=args.ckpt_dir,
        log_every=10,
        autotune=True,                       # DPT tunes the loader first
        autotune_budget_batches=4,
        step_config=TrainStepConfig(
            remat_policy="none", microbatches=1,
            optimizer=AdamWConfig(peak_lr=3e-4, warmup_steps=20,
                                  total_steps=steps)),
    )
    trainer = Trainer(model, loader, tcfg)
    summary = trainer.run()

    print("\n== training summary ==")
    print(f"resumed from step {trainer.start_step}" if trainer.start_step
          else "started from scratch")
    print(f"tuned loader   : workers={loader.params.num_workers} "
          f"prefetch={loader.params.prefetch_factor}")
    for rec in trainer.history[:3] + trainer.history[-3:]:
        print(f"  step {rec['step']:4d}  loss {rec['loss']:.4f}  "
              f"gnorm {rec['grad_norm']:.3f}  {rec['step_s']*1e3:.0f} ms/step")
    first, last = trainer.history[0], trainer.history[-1]
    assert last["loss"] < first["loss"], "loss did not improve"
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{summary['final_step']} steps ({summary['wall_s']:.1f}s); "
          f"checkpoints in {args.ckpt_dir}")
    print("re-run with --resume to continue from the latest checkpoint.")


if __name__ == "__main__":
    main()
